"""The paper's technique inside an MoE layer (DESIGN.md §2 site a).

Builds the qwen3-style MoE smoke model twice — once with the standard
capacity-bounded top-k router (drops overflow tokens) and once with the
CG router (overflow probes the token's next-choice experts) — and
compares drop rate, expert balance, and loss on a skewed batch.

  PYTHONPATH=src python examples/heterogeneous_moe.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model_zoo as zoo
from repro.moe.layer import init_moe_params, moe_ffn

base = configs.get_smoke_config("qwen3-moe-235b-a22b")
key = jax.random.PRNGKey(0)

print("=== router comparison on a skew-biased layer ===")
p = init_moe_params(key, base, jnp.bfloat16)
# bias the router hard toward expert 0 (a "hot key")
p["router"] = p["router"] + 5.0 * jax.nn.one_hot(0, base.moe.n_experts)
x = jax.random.normal(key, (2, 64, base.d_model), jnp.bfloat16)
for router in ("topk", "cg"):
    cfg = base.replace(moe=dataclasses.replace(base.moe, router=router))
    y, m = moe_ffn(x, p, cfg)
    print(f"  {router:5s} drop_frac={float(m['drop_frac']):.3f} "
          f"max_load_frac={float(m['max_load_frac']):.3f}")
print("  → CG turns dropped overflow slots into next-choice assignments")

print("\n=== heterogeneous expert capacity (Fig 15 on the expert axis) ===")
# capacity_skew=3 spreads the same total slot budget geometrically so
# cap_0/cap_{E-1} = 4 — experts on unequal hardware; overflow probing
# absorbs what the starved experts shed instead of dropping it
for router in ("topk", "cg"):
    cfg = base.replace(moe=dataclasses.replace(
        base.moe, router=router, capacity_skew=3.0))
    y, m = moe_ffn(x, p, cfg)
    print(f"  {router:5s} drop_frac={float(m['drop_frac']):.3f} "
          f"max_load_frac={float(m['max_load_frac']):.3f} "
          f"per-expert load={np.asarray(m['load']).round(1).tolist()}")
print("  → load tracks each expert's own cap_e; CG re-routes the shed")

print("\n=== one train step each on the full smoke model ===")
for router in ("topk", "cg"):
    cfg = base.replace(moe=dataclasses.replace(base.moe, router=router))
    params = zoo.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab)}
    loss = jax.jit(lambda p, b, c=cfg: zoo.loss_fn(p, c, b))(params, batch)
    print(f"  {router:5s} loss={float(loss):.4f}")
