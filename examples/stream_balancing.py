"""The paper's core experiment as a standalone script (Fig 10 / Fig 13).

A skewed WP-like stream hits a heterogeneous cluster; watch KG, SG and
CG queue behavior side by side, then change the machine capacities
mid-stream and watch CG re-adapt while the static schemes degrade.

  PYTHONPATH=src python examples/stream_balancing.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import cg, partitioners as P, simulation, streams

M, N, SLOT = 200_000, 10, 5_000

keys = streams.sample_trace(
    __import__("jax").random.PRNGKey(0), streams.WP_TRACE, M)

print("=== heterogeneous cluster: 3 of 10 workers are 5x faster ===")
caps = jnp.asarray(streams.heterogeneous_capacities(N, 3, 5.0) / 0.8,
                   jnp.float32)
kg = simulation.simulate_queues(P.key_grouping(keys, N), caps, N, SLOT)
sg = simulation.simulate_queues(P.shuffle_grouping(keys, N), caps, N, SLOT)
res = cg.run(cg.CGConfig(n_workers=N, alpha=10, eps=0.01, slot_len=SLOT),
             keys, caps)
for name, s in [("KG", kg.queue_spread), ("SG", sg.queue_spread),
                ("CG", res.queue_spread)]:
    arr = np.asarray(s)
    bars = "".join("▁▂▃▄▅▆▇█"[min(7, int(v / (arr.max() + 1e-9) * 7))]
                   for v in arr[:: max(1, len(arr) // 40)])
    print(f"  {name}: queue spread over time  {bars}  (end={arr[-1]:.0f})")
print(f"  CG made {int(res.moves)} paired virtual-worker moves")

print("\n=== capacities change at 1/3 and 2/3 of the stream (Fig 13) ===")
slots = M // SLOT
capsd = np.zeros((slots, N))
for start, c in streams.dynamic_capacity_schedule(N, M):
    capsd[start // SLOT:] = c / 0.8
res = cg.run(cg.CGConfig(n_workers=N, alpha=20, eps=0.01, slot_len=SLOT,
                         max_moves_per_slot=16),
             keys, jnp.asarray(capsd, jnp.float32))
imb = np.asarray(res.imbalance)
bars = "".join("▁▂▃▄▅▆▇█"[min(7, int(v / (imb.max() + 1e-9) * 7))]
               for v in imb)
print(f"  CG imbalance: {bars}")
print("  → spikes at each capacity change, then re-converges "
      f"({int(res.moves)} moves)")
