"""End-to-end training example: CG-sharded pipeline → AdamW → checkpoints.

Default: quick smoke (reduced arch, 20 steps). ``--preset 100m`` builds a
~100M-param dense model and trains a few hundred steps (the deliverable-
scale run); ``--arch`` trains any assigned architecture's smoke config.

  PYTHONPATH=src python examples/train_lm.py                    # quick
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse

from repro import configs
from repro.configs.base import ModelConfig
from repro.launch.train import train

PRESET_100M = ModelConfig(
    arch_id="dense-100m", family="dense",
    n_layers=12, d_model=640, n_heads=10, n_kv_heads=5, d_head=64,
    d_ff=1792, vocab=32_768, attn_chunk_threshold=1 << 30, remat="none")
# ≈ 100M params: 32768·640 embed + 12 × (0.64M attn + 3.4M mlp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=configs.ARCH_IDS)
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()

    if args.preset == "100m":
        import repro.configs as C
        # register the preset so the driver can resolve it
        class _Mod:
            CONFIG = PRESET_100M
            SMOKE = PRESET_100M
        C._MODULES["dense-100m"] = _Mod
        arch = "dense-100m"
    else:
        arch = args.arch

    losses = train(arch, n_steps=args.steps, batch=args.batch, seq=args.seq,
                   smoke=True, ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(10, args.steps // 5), log_every=5)
    print(f"\ntrained {len(losses)} steps: loss {losses[0]:.3f} → "
          f"{losses[-1]:.3f} (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
