"""Quickstart: the paper in 60 seconds.

Routes a skewed (zipf) stream with every partitioning scheme and shows
the paper's headline trade-off — then runs Consistent Grouping on a
heterogeneous cluster and watches it converge.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cg, metrics, partitioners as P, streams

M, N_KEYS, N_WORKERS = 100_000, 10_000, 10

print("=== 1. skewed stream, homogeneous workers: imbalance vs memory ===")
keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), M, N_KEYS, z=1.2)
caps = jnp.ones(N_WORKERS) / N_WORKERS
for scheme in P.ALL_SCHEMES:
    a = P.route(scheme, keys, N_WORKERS, eps=0.01)
    imb = float(metrics.normalized_imbalance(a, caps))
    mem = int(metrics.memory_footprint(a, keys, N_WORKERS, N_KEYS))
    print(f"  {scheme:5s} imbalance={imb:8.4f}  replicated-keys={mem:6d}")
print("  → KG: optimal memory, terrible balance; SG/PoTC: perfect balance,")
print("    n× memory; PoRC (the paper): bounded imbalance ≈ ε at ~KG memory")

print("\n=== 2. Consistent Grouping on a heterogeneous cluster ===")
# 3 of 10 workers are 5× more powerful (paper Fig 10), ρ = 0.8
hetero = jnp.asarray(
    streams.heterogeneous_capacities(N_WORKERS, y=3, zfac=5.0) / 0.8,
    jnp.float32)
res = cg.run(cg.CGConfig(n_workers=N_WORKERS, alpha=10, eps=0.01,
                         slot_len=5_000), keys, hetero)
imb = np.asarray(res.imbalance)
print(f"  imbalance over time: start={imb[:3].mean():.3f} "
      f"end={imb[-3:].mean():.3f}  (virtual-worker moves: {int(res.moves)})")
kg = P.key_grouping(keys, N_WORKERS)
from repro.core import simulation
kg_sim = simulation.simulate_queues(kg, hetero, N_WORKERS, 5_000)
print(f"  final queue spread:  CG={float(res.queue_spread[-1]):8.1f}   "
      f"KG={float(kg_sim.queue_spread[-1]):8.1f}")
print("  → CG discovers capacities from binary busy/idle signals alone")
