"""Serving example: batched decode behind the CG request router.

Four replicas of a small LM (one 20× slower — the paper's cpulimit
heterogeneity), a zipf-skewed session-key stream, and the CG router
pairing busy→idle virtual replicas from queue-occupancy signals.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma3-1b", "--requests", "48",
                "--decode-steps", "4", "--replicas", "4", "--hetero"]
    main()
