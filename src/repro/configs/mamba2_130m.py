"""mamba2-130m [ssm]: 24L d=768 (attn-free) vocab=50280, ssm_state=128 —
SSD state-space duality [arXiv:2405.21060; unverified]. d_inner=1536,
headdim=64 → 24 SSD heads, 1 group. O(1) decode state → runs long_500k.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, expand=2, chunk=128),
    subquadratic_decode=True,
    # 130M params, 24 SSD heads (∤16): TP is geometrically wasteful at
    # this size — run the 256-chip pod as pure data parallel (§Perf H3).
    pure_dp=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab=256,
    ssm=SSMConfig(d_state=16, head_dim=8, n_groups=1, expand=2, chunk=16),
    remat="none")
