"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.

GQA [arXiv:2403.17297; hf]. Pure full attention → long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=92_544,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk_threshold=1 << 30, remat="none")
