"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

CG router (the paper's technique): capacity (1+ε)·S·k/E with overflow
probing — see repro.moe. long_500k skipped (full attention).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151_936,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536,
                  capacity_factor=1.25, overflow_depth=4, router="cg"),
    rope_theta=1_000_000.0,
    # 235B MoE: microbatch so dispatch buffers fit v5e HBM (§Perf)
    grad_accum=8,
)

SMOKE = CONFIG.replace(
    grad_accum=1, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                  capacity_factor=1.25, overflow_depth=2, router="cg"),
    attn_chunk_threshold=1 << 30, remat="none")
