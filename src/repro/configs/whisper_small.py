"""whisper-small [audio]: 12L enc + 12L dec, d=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend STUB (precomputed frame embeddings)
[arXiv:2212.04356; unverified]. LayerNorm + GELU + biases. long_500k
skipped (enc-dec full attention).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_head=64, d_ff=3072, vocab=51_865,
    mlp_kind="gelu", norm_kind="ln", use_bias=True,
    # 242M params, d_model=768: TP over 16 is over-sharded — train as
    # pure data parallel on the full pod (§Perf, whisper iteration)
    pure_dp=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256, attn_chunk_threshold=1 << 30,
    remat="none")
