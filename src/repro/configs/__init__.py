"""Assigned-architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from . import (command_r_plus_104b, gemma3_1b, internlm2_20b, internvl2_2b,
               mamba2_130m, phi35_moe_42b_a6_6b, qwen3_moe_235b_a22b,
               starcoder2_3b, whisper_small, zamba2_2_7b)
from .base import SHAPES, ModelConfig, ShapeSpec  # noqa: F401

_MODULES = {
    "gemma3-1b": gemma3_1b,
    "internlm2-20b": internlm2_20b,
    "starcoder2-3b": starcoder2_3b,
    "command-r-plus-104b": command_r_plus_104b,
    "whisper-small": whisper_small,
    "mamba2-130m": mamba2_130m,
    "zamba2-2.7b": zamba2_2_7b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b_a6_6b,
    "internvl2-2b": internvl2_2b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].SMOKE


# long_500k applicability (DESIGN.md §4): sub-quadratic decode required.
def shape_applicable(arch_id: str, shape_name: str) -> bool:
    cfg = get_config(arch_id)
    if shape_name == "long_500k":
        return cfg.subquadratic_decode
    return True


def cells():
    """All 40 (arch, shape) cells with applicability flags."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            out.append((a, s, shape_applicable(a, s)))
    return out
