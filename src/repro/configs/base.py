"""Model/arch configuration schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25     # the paper's (1+eps) bound
    overflow_depth: int = 4           # extra PoRC probes past top_k
    router: str = "cg"                # "cg" (paper) | "topk" (drop baseline)
    # heterogeneous expert capacity (the Fig 15 unequal-worker story on
    # the expert axis). Exactly one of the two may be set; both unset =
    # uniform capacity, bit-identical to the scalar pre-vector dispatch.
    # expert_capacities: explicit per-expert buffer sizes (len n_experts,
    # absolute token slots per group — overrides capacity_factor).
    # capacity_skew s > 0: generated geometric profile cap_0/cap_{E-1} =
    # 1+s at the same total budget E·C_base (see
    # repro.moe.router.expert_capacity_vector).
    expert_capacities: tuple[int, ...] | None = None
    capacity_skew: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int                      # N
    head_dim: int = 64                # P
    n_groups: int = 1                 # G
    d_conv: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    chunk: int = 128                  # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    use_bias: bool = False
    tie_embeddings: bool = True
    mlp_kind: str = "swiglu"          # swiglu (3-mat) | gelu (2-mat)
    norm_kind: str = "rms"            # rms | ln
    # sliding-window / local-global interleave (gemma3)
    sliding_window: int | None = None
    global_every: int | None = None   # every k-th layer is global attention
    # sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba2): shared attention block applied every k ssm layers
    shared_attn_every: int | None = None
    # enc-dec (whisper)
    n_enc_layers: int = 0
    # vlm (internvl2): stub frontend embedding dim
    vision_dim: int | None = None
    n_patches: int = 256
    # numerics / compile hygiene
    dtype: str = "bfloat16"
    remat: str = "full"               # none|dots|full
    q_chunk: int = 1024
    kv_chunk: int = 1024
    attn_chunk_threshold: int = 2048  # use chunked attention above this seq
    use_pallas: str = "auto"          # auto|never|always
    # sub-quadratic decode support (long_500k applicability)
    subquadratic_decode: bool = False
    # small models on big meshes: batch over ALL axes, params replicated
    pure_dp: bool = False
    # gradient accumulation (microbatching): activations scale 1/k
    grad_accum: int = 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_params_embed(self) -> int:
        return self.vocab * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        if self.family in ("dense", "vlm"):
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
            nmat = 3 if self.mlp_kind == "swiglu" else 2
            mlp = nmat * d * self.d_ff
            per = attn + mlp + 2 * d
            tot = emb + L * per + d
            if self.family == "vlm" and self.vision_dim:
                tot += self.vision_dim * d
            return tot
        if self.family == "moe":
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
            router = d * self.moe.n_experts
            experts = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            shared = self.moe.n_shared_experts * 3 * d * self.moe.d_ff_expert
            per = attn + router + experts + shared + 2 * d
            return emb + L * per + d
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            H = d_in // s.head_dim
            per = d * (2 * d_in + 2 * s.n_groups * s.d_state + H) \
                + d_in * s.d_conv + d_in + H + d_in * d + 2 * d
            return emb + L * per + d
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            H = d_in // s.head_dim
            per = d * (2 * d_in + 2 * s.n_groups * s.d_state + H) \
                + d_in * s.d_conv + d_in + H + d_in * d + 2 * d
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d + 3 * d * self.d_ff + 2 * d
            return emb + L * per + attn + d
        if self.family == "audio":
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
            mlp = 2 * d * self.d_ff
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            return emb + enc + dec + 2 * d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
            + self.n_heads * self.d_head * d
        router = d * self.moe.n_experts
        act_experts = (self.moe.top_k + self.moe.n_shared_experts) * 3 * d * self.moe.d_ff_expert
        per = attn + router + act_experts + 2 * d
        return emb + L * per + d


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class SmokeSpec:
    """Reduced same-family config for CPU smoke tests."""
    seq_len: int = 64
    batch: int = 2
