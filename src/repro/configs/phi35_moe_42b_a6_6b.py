"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf].

CG router. long_500k skipped (full attention).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab=32_064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400,
                  capacity_factor=1.25, overflow_depth=4, router="cg"),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                  capacity_factor=1.25, overflow_depth=2, router="cg"),
    attn_chunk_threshold=1 << 30, remat="none")
