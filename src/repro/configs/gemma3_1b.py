"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global sliding-window interleave, 128k context
[hf:google/gemma-3-1b-pt; unverified]. Local window 1024; every 6th
layer is global full attention. Runs long_500k via the ring-buffer
local-KV decode path (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_head=256,
    d_ff=6912, vocab=262_144,
    sliding_window=1024, global_every=6,
    rope_theta=1_000_000.0,
    subquadratic_decode=True,
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab=256, sliding_window=16, global_every=3,
    attn_chunk_threshold=1 << 30, remat="none")
