"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA, RoPE [arXiv:2402.19173; hf]. StarCoder2 specifics honored: LayerNorm,
2-matrix GELU MLP, biases, sliding window 4096 on all layers.
long_500k skipped per assignment (full-attention lineage).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49_152,
    mlp_kind="gelu", norm_kind="ln", use_bias=True,
    sliding_window=4096, rope_theta=100_000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, sliding_window=16,
    attn_chunk_threshold=1 << 30, remat="none")
