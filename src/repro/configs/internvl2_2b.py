"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 —
InternViT + InternLM2 [arXiv:2404.16821; hf].

The InternViT frontend is a STUB per the assignment: input_specs
provides precomputed patch embeddings [B, 256, 1024] which are linearly
projected and prepended to the token stream. long_500k skipped
(full-attention backbone).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92_553,
    vision_dim=1024, n_patches=256,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, vision_dim=32, n_patches=8,
    attn_chunk_threshold=1 << 30, remat="none")
