"""The paper's own experimental configuration (simulation scale).

Matches §VII: 10 workers × 10 virtual workers, ε=0.01, thresholds
0.75/0.85, WP-like workload, ρ=0.8 provisioning.
"""
from repro.core.cg import CGConfig
from repro.core.streams import WP_TRACE, TW_TRACE  # noqa: F401

PAPER_CG = CGConfig(
    n_workers=10, alpha=10, eps=0.01,
    theta_busy=0.85, theta_idle=0.75,
    slot_len=10_000, max_moves_per_slot=8, inner="PORC",
    block_size=0,   # the paper routes one message per unit time — keep
                    # the exact oracle here; block_size>1 is the runtime
                    # fast path with its own staleness floor
)

RHO = 0.8                       # provisioning point (workers at 80%)
STORM_WORKERS = 24              # Fig 14/15 deployment
STORM_SOURCES = 8
SERVICE_MS_SWEEP = (0.1, 0.25, 0.5, 1.0)
CPULIMIT_FRACTION = 0.3         # two executors limited to 30%
