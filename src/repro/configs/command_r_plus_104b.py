"""command-r-plus-104b [dense]: 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01;
unverified]. Sequential residual blocks stand in for Cohere's parallel
block (same dims/FLOPs; DESIGN.md §9). long_500k skipped (full attn).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab=256_000,
    use_bias=False, rope_theta=75_000_000.0,
    # 104B × 4k tokens: microbatch so activations fit v5e HBM (§Perf)
    grad_accum=4,
)

SMOKE = CONFIG.replace(
    grad_accum=1, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, attn_chunk_threshold=1 << 30, remat="none")
