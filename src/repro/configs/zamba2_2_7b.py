"""zamba2-2.7b [hybrid]: 54L d=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Shared block applied every 6 SSM layers (9
invocations, one weight set). SSM decode is O(1) → runs long_500k with
seq-sharded KV for the shared block.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab=32_000,
    ssm=SSMConfig(d_state=64, head_dim=64, n_groups=1, expand=2, chunk=128),
    shared_attn_every=6,
    subquadratic_decode=True,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256,
    ssm=SSMConfig(d_state=16, head_dim=8, n_groups=1, expand=2, chunk=16),
    shared_attn_every=2, attn_chunk_threshold=1 << 30, remat="none")
