"""MoE routers: baseline top-k (drop) vs Consistent-Grouping (overflow).

The CG router is the paper's technique as a first-class MoE feature
(DESIGN.md §2): expert capacity is the (1+ε)·avg bound ((1+ε) =
``capacity_factor``), and a token-slot that would be *dropped* at a full
expert instead probes the token's next-preferred experts —
PoRC's salted-hash sequence with the gate ordering as the probe order.

Semantics match ``repro.kernels.ref.ref_cg_dispatch`` /
``repro.kernels.cg_dispatch`` (the Pallas kernel used on TPU); here the
pure-jnp path is used inside the model so the 512-device dry-run lowers
through stock XLA.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import ref_cg_dispatch


class RoutingResult(NamedTuple):
    assign: jnp.ndarray      # [T, k] expert per slot (-1 = dropped)
    slot: jnp.ndarray        # [T, k] position in expert buffer
    weights: jnp.ndarray     # [T, k] renormalized combine weights
    load: jnp.ndarray        # [E] expert occupancy
    aux_loss: jnp.ndarray    # [] Switch-style load-balance loss
    z_loss: jnp.ndarray      # [] router logit z-loss


def uniform_capacity(capacity_factor: float, T: int, k: int, E: int) -> int:
    """The (1+ε)·avg expert buffer bound, C = ⌈-ish⌉ cf·T·k/E.

    Single source of truth for the capacity formula — ``route`` sizes
    the dispatch against it and ``moe/layer.moe_ffn`` sizes the
    [B, E, C, D] buffers from the same numbers; any drift silently
    corrupts the slot→token inverse permutation.
    """
    return max(1, int(capacity_factor * T * k / E))


def expert_capacity_vector(moe, T: int) -> tuple[int, ...]:
    """Per-expert capacities as static python ints, length E.

    Resolution order: explicit ``moe.expert_capacities`` (absolute slot
    counts) > ``moe.capacity_skew`` generator > uniform
    :func:`uniform_capacity`. The skew generator keeps the total budget
    at E·C_base and spreads it geometrically so that
    cap_0 / cap_{E-1} = 1 + skew — the paper's Fig 15 heterogeneous
    worker capacities transplanted onto the expert axis (overflow
    probing absorbs what the small experts shed).
    """
    E, k = moe.n_experts, moe.top_k
    if moe.expert_capacities is not None:
        caps = tuple(int(c) for c in moe.expert_capacities)
        if len(caps) != E:
            raise ValueError(
                f"expert_capacities has {len(caps)} entries, expected {E}")
        if any(c < 1 for c in caps):
            raise ValueError(f"expert capacities must be >= 1: {caps}")
        return caps
    base = uniform_capacity(moe.capacity_factor, T, k, E)
    skew = float(getattr(moe, "capacity_skew", 0.0) or 0.0)
    if skew < 0:
        raise ValueError(f"capacity_skew must be >= 0: {skew}")
    if skew == 0.0 or E == 1:
        return (base,) * E
    w = [(1.0 + skew) ** (-i / (E - 1)) for i in range(E)]
    total = E * base
    wsum = sum(w)
    return tuple(max(1, int(round(total * wi / wsum))) for wi in w)


def _aux_losses(logits: jnp.ndarray, assign: jnp.ndarray, n_experts: int):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # fraction of slots landing on each expert
    onehot = jax.nn.one_hot(jnp.where(assign < 0, n_experts, assign),
                            n_experts + 1, dtype=jnp.float32)[..., :n_experts]
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)            # [E]
    p = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(f * p)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return aux, z


def route(x: jnp.ndarray, router_w: jnp.ndarray, moe, *,
          block: int | None = None) -> RoutingResult:
    """Route one token group. x: [T, D]; router_w: [D, E]."""
    T = x.shape[0]
    E, k = moe.n_experts, moe.top_k
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    depth = k if moe.router == "topk" else min(E, k + moe.overflow_depth)
    gates, pref = jax.lax.top_k(probs, depth)
    caps = expert_capacity_vector(moe, T)
    if block is None:
        block = min(128, T)
    if len(set(caps)) == 1:
        # uniform: keep the scalar path (bit-identical trace to pre-
        # vector dispatch; parity-gated in tests/test_cg_dispatch_properties)
        assign, slot, weights, load = ref_cg_dispatch(
            pref.astype(jnp.int32), gates, n_experts=E, k=k,
            capacity=caps[0], block=block)
    else:
        assign, slot, weights, load = ref_cg_dispatch(
            pref.astype(jnp.int32), gates, n_experts=E, k=k,
            capacities=jnp.asarray(caps, jnp.float32), block=block)
    aux, z = _aux_losses(logits, assign, E)
    return RoutingResult(assign, slot, weights, load, aux, z)
