"""MoE routers: baseline top-k (drop) vs Consistent-Grouping (overflow).

The CG router is the paper's technique as a first-class MoE feature
(DESIGN.md §2): expert capacity is the (1+ε)·avg bound ((1+ε) =
``capacity_factor``), and a token-slot that would be *dropped* at a full
expert instead probes the token's next-preferred experts —
PoRC's salted-hash sequence with the gate ordering as the probe order.

Semantics match ``repro.kernels.ref.ref_cg_dispatch`` /
``repro.kernels.cg_dispatch`` (the Pallas kernel used on TPU); here the
pure-jnp path is used inside the model so the 512-device dry-run lowers
through stock XLA.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import ref_cg_dispatch


class RoutingResult(NamedTuple):
    assign: jnp.ndarray      # [T, k] expert per slot (-1 = dropped)
    slot: jnp.ndarray        # [T, k] position in expert buffer
    weights: jnp.ndarray     # [T, k] renormalized combine weights
    load: jnp.ndarray        # [E] expert occupancy
    aux_loss: jnp.ndarray    # [] Switch-style load-balance loss
    z_loss: jnp.ndarray      # [] router logit z-loss


def _aux_losses(logits: jnp.ndarray, assign: jnp.ndarray, n_experts: int):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # fraction of slots landing on each expert
    onehot = jax.nn.one_hot(jnp.where(assign < 0, n_experts, assign),
                            n_experts + 1, dtype=jnp.float32)[..., :n_experts]
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)            # [E]
    p = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(f * p)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return aux, z


def route(x: jnp.ndarray, router_w: jnp.ndarray, moe, *,
          block: int | None = None) -> RoutingResult:
    """Route one token group. x: [T, D]; router_w: [D, E]."""
    T = x.shape[0]
    E, k = moe.n_experts, moe.top_k
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    depth = k if moe.router == "topk" else min(E, k + moe.overflow_depth)
    gates, pref = jax.lax.top_k(probs, depth)
    capacity = max(1, int(moe.capacity_factor * T * k / E))
    if block is None:
        block = min(128, T)
    assign, slot, weights, load = ref_cg_dispatch(
        pref.astype(jnp.int32), gates, n_experts=E, k=k,
        capacity=capacity, block=block)
    aux, z = _aux_losses(logits, assign, E)
    return RoutingResult(assign, slot, weights, load, aux, z)
