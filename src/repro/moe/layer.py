"""Expert-parallel MoE FFN layer with CG routing.

Token groups: the batch dimension is the group axis (one group per
sequence — the "source" in the paper's terms); every group routes its
S·k slots against per-expert capacity (1+ε)·S·k/E. Dispatch/combine are
scatter/gather into [B, E, C, D] buffers — B sharded on the data axis,
E on the model axis, so GSPMD lowers the group→expert exchange into the
EP all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# NOTE: imported from the submodule lazily in the functions below to
# avoid the repro.models ↔ repro.moe import cycle (models.moe_transformer
# imports this module).
from .router import RoutingResult, expert_capacity_vector, route


def _layers():
    from repro.models import layers
    return layers


def init_moe_params(key, cfg, dtype):
    dense_init = _layers().dense_init
    moe = cfg.moe
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w1": dense_init(ks[1], (E, d, f), dtype),
        "w3": dense_init(ks[2], (E, d, f), dtype),
        "w2": dense_init(ks[3], (E, f, d), dtype),
    }
    if moe.n_shared_experts:
        fs = moe.n_shared_experts * f
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": dense_init(kss[0], (d, fs), dtype),
            "w3": dense_init(kss[1], (d, fs), dtype),
            "w2": dense_init(kss[2], (fs, d), dtype),
        }
    return p


def moe_ffn(x: jnp.ndarray, p, cfg):
    """x: [B, S, D] → ([B, S, D], aux_metrics dict).

    Dispatch is GSPMD-friendly: the only scatter is over int32 *indices*
    (the slot→token inverse permutation, ~MBs); token rows then move via
    gathers whose outputs carry the expert-parallel sharding, so the
    partitioner lowers them into the EP exchange instead of replicating
    activations.
    """
    shard_act = _layers().shard_act
    moe = cfg.moe
    B, S, D = x.shape
    E, k = moe.n_experts, moe.top_k
    T = S
    # per-expert capacities from the router's single source of truth;
    # buffers pad every expert to C_max (ragged cap_e enforced by the
    # dispatch: slot < cap_e, so smaller experts just leave zero rows)
    caps = expert_capacity_vector(moe, T)
    capacity = max(caps)
    cap_arr = jnp.asarray(caps, jnp.float32)

    r: RoutingResult = jax.vmap(
        lambda xg: route(xg, p["router"], moe))(x)           # leaves [B, ...]

    # ---- inverse permutation: which token fills expert slot [e, c] ----
    flat_idx = jnp.where(r.assign >= 0,
                         r.assign * capacity + r.slot, E * capacity)  # [B,T,k]
    tok_idx = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :, None],
                               flat_idx.shape)
    slot_token = jnp.full((B, E * capacity + 1), T, jnp.int32)
    slot_token = slot_token.at[
        jnp.arange(B)[:, None, None], flat_idx].set(tok_idx)
    slot_token = slot_token[:, : E * capacity]               # [B, E*C]

    # ---- dispatch: gather token rows into expert buffers ----
    xp = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    buf = jnp.take_along_axis(xp, slot_token[..., None], axis=1)
    buf = buf.reshape(B, E, capacity, D)
    buf = shard_act(buf, "becd")

    # ---- expert compute (E sharded on model axis) ----
    h = jnp.einsum("becd,edf->becf", buf, p["w1"])
    g = jnp.einsum("becd,edf->becf", buf, p["w3"])
    h = jax.nn.silu(h) * g
    out = jnp.einsum("becf,efd->becd", h, p["w2"])
    out = shard_act(out, "becd")

    # ---- combine: gather expert outputs back to token slots ----
    out_flat = out.reshape(B, E * capacity, D)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((B, 1, D), out.dtype)], axis=1)  # sentinel row
    gathered = jnp.take_along_axis(
        out_flat, flat_idx.reshape(B, T * k)[..., None], axis=1)
    gathered = gathered.reshape(B, T, k, D)
    y = jnp.sum(gathered * r.weights[..., None].astype(out.dtype), axis=2)

    if moe.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w1"]) * (x @ sp["w3"])
        y = y + hs @ sp["w2"]

    metrics = {
        "aux_loss": jnp.mean(r.aux_loss),
        "z_loss": jnp.mean(r.z_loss),
        "drop_frac": jnp.mean((r.assign < 0).astype(jnp.float32)),
        # worst per-expert utilization load/cap_e (== load/C pre-vector;
        # must stay <= 1: the dispatch never overfills any expert)
        "max_load_frac": jnp.max(r.load / cap_arr[None, :]),
        "load": jnp.mean(r.load, axis=0),                 # [E] per group
    }
    return y, metrics
