"""MoE with Consistent-Grouping routing (the paper's technique, site a)."""
from .layer import init_moe_params, moe_ffn  # noqa: F401
from .router import RoutingResult, route  # noqa: F401
