from .engine import CGRequestRouter, ServingEngine  # noqa: F401
from .mesh import MeshCGRequestRouter  # noqa: F401
