from .engine import CGRequestRouter, ServingEngine  # noqa: F401
