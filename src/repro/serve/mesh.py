"""Mesh-sharded CG request router — serving on the production topology.

``CGRequestRouter`` simulates the paper's distributed sources as a vmap
axis in one process; this router puts them on a JAX device mesh
(``launch.mesh.make_source_mesh``): each host owns its
``delta [S_local, n_bins]`` lane, per-block routing runs under
``shard_map`` and the ``sync_every`` delta-merge is a ``jax.lax.psum``
across the ``sources`` axis (``kernels.mesh``). Routing is
bit-identical to the vmapped engine at matching config — CI gates the
``sync_every=1`` case.

The VW→replica owner map is the other piece of shared state: it
replicates across the mesh through a ``delegation.VersionedOwnerMap``.
Every rebalance/evacuation *commits* a new version atomically;
``owner_sync_every`` commits later (1 = immediately) the routers
*adopt* it. Until adoption the submit path gathers owners from the
base snapshot — a stale router routes on the pre-move map, which is
merely conservative, never torn. Forced updates (evacuation, an
explicit ``vw_owner`` assignment, restores) adopt immediately: routing
to a dead replica is a correctness problem, a missed rebalance move is
not.

Usage::

    mesh = make_source_mesh()            # all local devices
    router = MeshCGRequestRouter(n_replicas=4, n_sources=8, mesh=mesh)
    engine = ServingEngine(fns, router, async_submit=True)

See docs/multihost.md for the mesh layout and the 8-host demo
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import delegation
from repro.kernels.mesh import (SOURCES_AXIS, mesh_porc_multisource,
                                shard_multisource_state)
from repro.launch.mesh import make_source_mesh
from repro.serve.engine import CGRequestRouter


@dataclass
class MeshCGRequestRouter(CGRequestRouter):
    """``CGRequestRouter`` with source lanes and routing state on a
    device mesh. Drop-in for the single-host router wherever
    ``hh_scheme`` is off; ``n_sources`` must be a multiple of the
    mesh's host count (each host owns ``n_sources / H`` lanes).

    ``mesh`` defaults to a fresh 1-D ``("sources",)`` mesh over every
    local device; ``owner_sync_every`` is how many rebalance commits a
    router may lag the owner map before adopting (1 = every commit,
    single-host parity).
    """
    mesh: object = None
    owner_sync_every: int = 1

    def __post_init__(self):
        if self.hh_scheme:
            raise NotImplementedError(
                "heavy-hitter probe policies are not mesh-sharded yet; "
                "use CGRequestRouter for hh_scheme routing")
        super().__post_init__()
        if self.mesh is None:
            self.mesh = make_source_mesh()
        H = self.mesh.shape[SOURCES_AXIS]
        if self.n_sources % H:
            raise ValueError(
                f"n_sources={self.n_sources} must be a multiple of the "
                f"mesh's {H} hosts (each host owns n_sources/H lanes)")
        self._state = shard_multisource_state(self._state, self.mesh)
        self._omap = delegation.VersionedOwnerMap(self._dstate.vw_owner,
                                                  mesh=self.mesh)
        self._commits_behind = 0

    # -- versioned owner propagation --------------------------------------
    @property
    def owner_version(self) -> int:
        """Version of the latest committed owner map (monotonic)."""
        return self._omap.version

    @property
    def owner_adopted_version(self) -> int:
        """Version the routers are currently routing against."""
        return self._omap.base_version

    def _owner_view(self):
        # the snapshot a router at the adopted version sees: the head
        # when fully synced, otherwise the base fallback
        return self._omap.view(self._omap.base_version)

    def _note_owner_update(self, force: bool = False) -> None:
        self._omap.commit(self._dstate.vw_owner)
        self._commits_behind += 1
        if force or self._commits_behind >= self.owner_sync_every:
            self._omap.adopt()
            self._commits_behind = 0

    # -- sharded submit path ----------------------------------------------
    def dispatch_batch(self, keys: np.ndarray):
        """Routing half of the submit path, on the mesh: the batch
        splits round-robin across the source lanes, each host routes
        its lanes against base + its own deltas under ``shard_map``,
        and the delta-merge is a psum over the ``sources`` axis. Same
        handle contract as the base class."""
        keys = np.asarray(keys, np.int32)
        self._maybe_rebase()
        assign_vw, self._state = mesh_porc_multisource(
            jnp.asarray(keys), self.n_virtual, self.mesh,
            n_sources=self.n_sources, sync_every=self.sync_every,
            block=self.block_size, eps=self.eps, state=self._state)
        self._routed += len(keys)
        return assign_vw
