"""Batched serving engine with CG request routing (paper site c).

Replicas are the *workers* (possibly heterogeneous — different chip
generations or cpulimit'ed fractions, exactly Fig. 15's setup); request
streams are keyed (session/tenant id — skewed in practice) and routed
by PoRC onto *virtual replicas*, which CG pairing re-assigns as
replicas signal busy/idle from their queue occupancy — the paper's
queue-length utilization signal (§VII "Monitoring Performance").

The engine is single-process here (replicas are model states on the
same mesh or plain callables in tests); the routing layer is the part
that scales out.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.hashing import hash_to_bins
from repro.kernels.ref import (multisource_merge, multisource_state_init,
                               ref_porc_multisource)
import jax.numpy as jnp


@dataclass
class ReplicaState:
    queue: deque = field(default_factory=deque)
    served: int = 0
    busy_signal: bool = False
    idle_signal: bool = False


@dataclass
class CGRequestRouter:
    """PoRC + virtual-replica assignment for incoming request keys.

    Routing state lives on device as a ``MultiSourcePorcState`` and
    stays there across ``route_batch`` calls — the host only mirrors the
    integer message count, so the steady-state submit path never
    round-trips the load vectors through NumPy. ``n_sources > 1`` shards
    each batch round-robin across that many source lanes (§V-C: each
    lane routes against its local view, delta-merged every
    ``sync_every`` blocks); ``n_sources=1`` is the single-source block
    path, bit-identical to the previous engine.
    """
    n_replicas: int
    alpha: int = 8
    eps: float = 0.05
    queue_hi: float = 0.85        # of max_queue → busy
    queue_lo: float = 0.5
    max_queue: int = 256
    block_size: int = 128         # PoRC messages per load snapshot;
                                  # 1 = exact per-message Alg. 1
    n_sources: int = 1            # source lanes a batch is sharded over
    sync_every: int = 1           # blocks between lane delta-merges

    def __post_init__(self):
        self.n_virtual = self.n_replicas * self.alpha
        self.vw_owner = np.repeat(np.arange(self.n_replicas), self.alpha)
        self._state = multisource_state_init(self.n_virtual, self.n_sources)
        self._routed = 0
        self.moves = 0

    @property
    def vw_load(self) -> np.ndarray:
        """Merged per-VW load (base + unpublished lane deltas), as a
        fresh NumPy array — a device download, for monitoring/rebalance.
        Assigning to it reseeds the base load and clears the deltas."""
        s = self._state
        return np.asarray(s.base + s.delta.sum(0))

    @vw_load.setter
    def vw_load(self, value) -> None:
        value = np.asarray(value, np.float32)
        self._state = self._state._replace(
            base=jnp.asarray(value),
            delta=jnp.zeros_like(self._state.delta))
        # conservation invariant: routed == total load. Re-deriving it
        # here keeps the host-side rebase trigger sound after a state
        # restore that only seeds the loads; assign ``routed`` after
        # this to override the clock explicitly.
        self.routed = int(value.sum())

    @property
    def routed(self) -> int:
        return self._routed

    @routed.setter
    def routed(self, value) -> None:
        self._routed = int(value)
        self._state = self._state._replace(routed=jnp.float32(self._routed))

    def _maybe_rebase(self) -> None:
        # The engine carries load/routed as f32: past 2^24 a +1.0 becomes
        # a silent no-op and balancing would collapse onto "frozen" VWs.
        # Rebase by the min load first (shifts the capacity check by only
        # eps·base, and keeps every counter far from the f32 ceiling).
        # The trigger is the (1+eps)·m/n envelope plus the staleness
        # bound — a host-side bound on the true max load, so the hot
        # path never waits on a device readback.
        stale = max(self.block_size, 1) * self.n_sources * self.sync_every
        if (1.0 + self.eps) * self._routed / self.n_virtual + stale < 2 ** 23:
            return
        shift = float(jnp.min(self._state.base + self._state.delta.sum(0)))
        self._routed -= int(shift * self.n_virtual)
        self._state = self._state._replace(
            base=self._state.base - shift,
            routed=jnp.float32(self._routed))

    def route(self, key: int) -> int:
        """PoRC over virtual replicas (Alg. 1), then owner lookup.

        Pure-python sequential oracle — ``route_batch`` with
        ``block_size=1`` is bit-identical to a sequence of these calls.
        Lane deltas are flushed first (a forced sync), so the probe
        chain sees the true global load.
        """
        self._maybe_rebase()
        if self.n_sources > 1 or self.sync_every > 1:
            state = multisource_merge(self._state)    # flush lane deltas
        else:
            state = self._state                       # deltas provably empty
        load = np.array(state.base)                   # writable host copy
        self._routed += 1
        cap = (1.0 + self.eps) * self._routed / self.n_virtual
        salt = 1
        vw = int(hash_to_bins(jnp.int32(key), salt, self.n_virtual))
        while load[vw] >= cap and salt < 4 * self.n_virtual:
            salt += 1
            vw = int(hash_to_bins(jnp.int32(key), salt, self.n_virtual))
        if load[vw] >= cap:
            vw = int(np.argmin(load))
        load[vw] += 1
        self._state = state._replace(
            base=jnp.asarray(load, jnp.float32),
            routed=jnp.float32(self._routed))
        return int(self.vw_owner[vw])

    def route_batch(self, keys: np.ndarray) -> np.ndarray:
        """Sharded block-parallel PoRC over virtual replicas (the
        default submit path). The batch splits round-robin across
        ``n_sources`` lanes routed concurrently (vmapped); load state
        stays device-resident across calls. A trailing partial block
        routes as power-of-two sub-blocks, so no padding keys ever
        pollute the load state and arbitrary batch sizes compile only
        O(log block_size) remainder programs."""
        keys = np.asarray(keys, np.int32)
        self._maybe_rebase()
        assign_vw, self._state = ref_porc_multisource(
            jnp.asarray(keys), self.n_virtual, self.n_sources,
            sync_every=self.sync_every, block=self.block_size,
            eps=self.eps, state=self._state)
        self._routed += len(keys)
        return self.vw_owner[np.asarray(assign_vw)]

    def rebalance(self, busy: list[int], idle: list[int]) -> int:
        """Paired moves: one virtual replica per (busy, idle) pair."""
        moved = 0
        loads = self.vw_load                  # one device download
        for b, i in zip(busy, idle):
            owned = np.flatnonzero(self.vw_owner == b)
            if len(owned) == 0:
                continue
            # move the most-loaded virtual replica (greatest relief)
            vw = owned[np.argmax(loads[owned])]
            self.vw_owner[vw] = i
            moved += 1
        self.moves += moved
        return moved


class ServingEngine:
    """Queue-per-replica engine. ``replica_fns`` map a batch of request
    payloads to outputs; service speed differences model heterogeneity."""

    def __init__(self, replica_fns, router: CGRequestRouter | None = None,
                 max_batch: int = 8):
        self.replicas = [ReplicaState() for _ in replica_fns]
        self.fns = list(replica_fns)
        self.router = router or CGRequestRouter(len(replica_fns))
        self.max_batch = max_batch
        self.latencies: list[float] = []

    def submit(self, key: int, payload) -> None:
        """Single-request submit — routed through the batch path (a
        batch of one is one block of one, i.e. exact Alg. 1)."""
        self.submit_batch(np.asarray([key], np.int32), [payload])

    def submit_batch(self, keys: np.ndarray, payloads) -> None:
        assign = self.router.route_batch(np.asarray(keys, np.int32))
        now = time.monotonic()
        for r, p in zip(assign, payloads):
            self.replicas[int(r)].queue.append((now, p))

    def step(self) -> int:
        """One engine tick: each replica serves up to max_batch requests,
        then delegation signals fire and the router re-pairs."""
        served = 0
        for i, (rep, fn) in enumerate(zip(self.replicas, self.fns)):
            batch = []
            while rep.queue and len(batch) < self.max_batch:
                batch.append(rep.queue.popleft())
            if batch:
                fn([p for _, p in batch])
                now = time.monotonic()
                self.latencies.extend(now - t for t, _ in batch)
                rep.served += len(batch)
                served += len(batch)
            occ = len(rep.queue) / self.router.max_queue
            rep.busy_signal = occ > self.router.queue_hi
            rep.idle_signal = occ < self.router.queue_lo
        busy = [i for i, r in enumerate(self.replicas) if r.busy_signal]
        idle = [i for i, r in enumerate(self.replicas) if r.idle_signal]
        if busy and idle:
            self.router.rebalance(busy, idle)
        return served

    def queue_depths(self) -> list[int]:
        return [len(r.queue) for r in self.replicas]
