"""Batched serving engine with CG request routing (paper site c).

Replicas are the *workers* (possibly heterogeneous — different chip
generations or cpulimit'ed fractions, exactly Fig. 15's setup); request
streams are keyed (session/tenant id — skewed in practice) and routed
by PoRC onto *virtual replicas*, which CG pairing re-assigns as
replicas signal busy/idle from their queue occupancy — the paper's
queue-length utilization signal (§VII "Monitoring Performance").

The routing layer is the part that scales out, and it does:
``serve.mesh.MeshCGRequestRouter`` puts the source lanes and routing
state on a JAX device mesh via ``shard_map`` (see docs/multihost.md).
The replica drain loop stays host-side (replicas are model states on
the same mesh or plain callables in tests); ``async_submit=True``
overlaps the sharded routing dispatch with the previous tick's drain.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from repro.core import controller, delegation
from repro.core.hashing import hash_to_bins
from repro.kernels.ref import (multisource_merge, multisource_state_init,
                               ref_porc_multisource)
import jax.numpy as jnp


class Request(NamedTuple):
    """One queued request. ``t``/``step`` are the *original* submit
    time/tick — retries keep them, so latency always measures from first
    submission (failures make requests slower, never younger). ``enq``
    is the tick of the most recent (re-)enqueue: the head-of-line
    timeout measures from it, so a retry gets a fresh timeout window
    instead of being instantly stale on a healthy replica."""
    t: float          # wall-clock submit time (monotonic)
    step: int         # engine tick at submit
    key: int          # routing key (needed to re-route on retry)
    payload: object
    attempts: int = 0  # completed re-routes (0 = first delivery)
    enq: int = 0       # engine tick of the last (re-)enqueue


@dataclass
class ReplicaState:
    queue: deque = field(default_factory=deque)
    served: int = 0
    busy_signal: bool = False
    idle_signal: bool = False
    alive: bool = True            # process up: serving and heartbeating
    slow_factor: float = 1.0      # service capacity divisor (chaos
                                  # "slow"; 1.0 = nominal)


@dataclass
class CGRequestRouter:
    """PoRC + virtual-replica assignment for incoming request keys.

    Routing state lives on device as a ``MultiSourcePorcState`` and
    stays there across ``route_batch`` calls — the host only mirrors the
    integer message count, so the steady-state submit path never
    round-trips the load vectors through NumPy. ``n_sources > 1`` shards
    each batch round-robin across that many source lanes (§V-C: each
    lane routes against its local view, delta-merged every
    ``sync_every`` blocks); ``n_sources=1`` is the single-source block
    path, bit-identical to the previous engine.

    Delegation runs through the shared ``repro.core.delegation`` engine:
    the virtual-replica owner map, the windowed per-VW rates and the
    FCFS signal queues are device-resident (``rebalance`` is one jitted
    call — no per-VW host loop, no NumPy round-trip of the load vector),
    pairing is severity-ordered with FCFS carry-over across rebalance
    ticks, and ``capacity_weighted=True`` sheds VWs from a slow replica
    until its share matches its measured capacity.

    ``adaptive_moves``/``hysteresis`` add the closed-loop controller
    (``repro.core.controller``): the per-tick move budget follows the
    EWMA'd replica queue depths instead of the static
    ``max_moves_per_rebalance``, and busy/idle signals latch between
    separate enter/exit occupancy levels with a dwell so a replica
    hovering at ``queue_hi`` stops flapping. See ``docs/tuning.md``.

    ``hh_scheme`` ("d"/"w") turns on heavy-hitter-aware probe depths
    (D/W-Choices): a device-resident count-min sketch rides the routing
    state, hot session/tenant keys get up to ``d_heavy`` (or all-VW)
    probe choices while the tail keeps ``d_tail`` — bounding per-key
    replica fan-out and queue imbalance at once. Off ("") routes
    bit-identically to the policy-free engine. See docs/partitioners.md.
    """
    n_replicas: int
    alpha: int = 8
    eps: float = 0.05
    queue_hi: float = 0.85        # of max_queue → busy
    queue_lo: float = 0.5
    max_queue: int = 256
    block_size: int = 128         # PoRC messages per load snapshot;
                                  # 1 = exact per-message Alg. 1
    n_sources: int = 1            # source lanes a batch is sharded over
    sync_every: int = 1           # blocks between lane delta-merges
    capacity_weighted: bool = False  # budgets ∝ measured capacity share
    rate_decay: float = 0.6       # EWMA decay of per-VW rates per
                                  # rebalance tick (1.0 = cumulative)
    max_moves_per_rebalance: int = 8
    adaptive_moves: bool = False  # per-tick move budget from queue
                                  # depth (repro.core.controller),
                                  # clamped [min_moves, max_moves_per_rebalance]
    per_worker_budgets: bool = False  # adaptive budget as an [n] vector
                                  # (each replica's own depth excess
                                  # caps its shed count) instead of one
                                  # fleet-wide scalar; needs
                                  # adaptive_moves
    min_moves: int = 1            # adaptive budget floor
    depth_decay: float = 0.5      # EWMA decay of replica queue depths
    hysteresis: bool = False      # latch busy/idle between enter/exit
                                  # occupancy levels + dwell
    queue_exit_margin: float = 0.1  # busy exits below queue_hi-margin,
                                  # idle exits above queue_lo+margin
    dwell: int = 3                # ticks a raw signal must persist
    hh_scheme: str = ""           # heavy-hitter probe policy: "" = off
                                  # (bit-identical to the plain engine),
                                  # "d" = D-Choices, "w" = W-Choices
                                  # ("DCHOICES"/"WCHOICES" also accepted)
    sketch_depth: int = 4         # count-min rows
    sketch_width: int = 4096      # count-min columns per row
    hot_fraction: float = 1e-3    # heavy when est >= fraction of routed
    engine: str = "auto"          # PORC block-engine implementation:
                                  # "ref" (jnp scan) | "pallas" (Pallas
                                  # kernel, bit-identical) | "auto" =
                                  # Pallas on TPU, jnp elsewhere
    d_heavy: int = 32             # heavy-key probe ceiling under "d"
    d_tail: int = 2               # tail-key probe budget
    hh_headroom: float = 2.0      # schedule slack over the Eq.-2 spread
    state_bytes_per_request: float = 0.0  # per-request keyed-state
                                  # growth (KV-cache-like); > 0 turns on
                                  # per-VW state-size accounting
    byte_budget_per_rebalance: float = 0.0  # max VW state bytes one
                                  # rebalance may migrate (0 = unmetered)
    min_gain_per_byte: float = 0.0  # cost-benefit: move a VW only if
                                  # its rate ≥ this · its state bytes

    def __post_init__(self):
        self.n_virtual = self.n_replicas * self.alpha
        if self.per_worker_budgets and not self.adaptive_moves:
            raise ValueError("per_worker_budgets requires adaptive_moves"
                             " (the budgets are the adaptive ones)")
        if self.hh_scheme:
            from repro.core.cg import _hh_letter
            from repro.kernels.ref import HHPolicy
            self._policy = HHPolicy(
                scheme=_hh_letter(self.hh_scheme), depth=self.sketch_depth,
                width=self.sketch_width, hot_fraction=self.hot_fraction,
                d_heavy=self.d_heavy, d_tail=self.d_tail,
                headroom=self.hh_headroom)
        else:
            self._policy = None
        self._state = multisource_state_init(self.n_virtual, self.n_sources,
                                             policy=self._policy)
        self._routed = 0
        self.moves = 0
        self._dcfg = delegation.DelegationConfig(
            n_workers=self.n_replicas, n_virtual=self.n_virtual,
            max_moves_per_slot=self.max_moves_per_rebalance,
            capacity_weighted=self.capacity_weighted,
            rate_decay=self.rate_decay, fcfs=True,
            byte_budget_per_slot=self.byte_budget_per_rebalance,
            min_gain_per_byte=self.min_gain_per_byte)
        # per-VW state sizes (bytes) — None until the caller assigns
        # vw_state_bytes or state_bytes_per_request starts accruing them;
        # None keeps the rebalance path bit-identical to the cost-free
        # engine.
        self._vw_bytes: np.ndarray | None = (
            np.zeros(self.n_virtual, np.float64)
            if self.state_bytes_per_request > 0 else None)
        self._dstate = delegation.init_state(
            self._dcfg,
            vw_owner=jnp.repeat(jnp.arange(self.n_replicas, dtype=jnp.int32),
                                self.alpha))
        self._rated_load = jnp.zeros(self.n_virtual, jnp.float32)
        # host mirror of "any signal carried in the FCFS queues", so the
        # no-candidate early return never strands a carried signal
        self._queued_busy = False
        self._queued_idle = False
        # the adaptive controller (queue-depth budgets + hysteresis
        # latches); None keeps the static-budget raw-signal path
        if self.adaptive_moves or self.hysteresis:
            self._controller = controller.DelegationController.from_thresholds(
                controller.ControllerConfig(
                    n_workers=self.n_replicas,
                    adaptive_moves=self.adaptive_moves,
                    per_worker_budget=self.per_worker_budgets,
                    min_moves=self.min_moves,
                    max_moves=self.max_moves_per_rebalance,
                    depth_decay=self.depth_decay,
                    hysteresis=self.hysteresis, dwell=self.dwell,
                    byte_budget=self.byte_budget_per_rebalance),
                theta_busy=self.queue_hi, theta_idle=self.queue_lo,
                margin=self.queue_exit_margin)
        else:
            self._controller = None
        self._rebalance_mark = 0    # routed count at the last rebalance

    @property
    def controller_active(self) -> bool:
        return self._controller is not None

    @property
    def flap_count(self) -> int:
        """Cumulative busy/idle signal flips (controller telemetry)."""
        return self._controller.flaps if self._controller else 0

    @property
    def last_budget(self) -> int:
        """The move budget the controller set at the last rebalance."""
        return (self._controller.last_budget if self._controller
                else self.max_moves_per_rebalance)

    @property
    def vw_owner(self) -> np.ndarray:
        """Virtual-replica → replica map, as a fresh NumPy download (the
        authoritative copy is device-resident). Assign to replace it."""
        return np.asarray(self._dstate.vw_owner)

    @vw_owner.setter
    def vw_owner(self, value) -> None:
        self._dstate = self._dstate._replace(
            vw_owner=jnp.asarray(value, jnp.int32))
        self._note_owner_update(force=True)

    def _owner_view(self):
        """The owner map the submit path gathers from (device array).
        The mesh router overrides this with its versioned replicated
        view; here the live map is the only copy."""
        return self._dstate.vw_owner

    def _note_owner_update(self, force: bool = False) -> None:
        """Hook: the authoritative owner map just changed (rebalance,
        evacuation or direct assignment). The mesh router commits a new
        version here; single-host routing needs nothing (the live map
        is what ``_owner_view`` returns). ``force`` marks changes that
        must reach every router at once (evacuation, restores)."""

    @property
    def vw_state_bytes(self) -> np.ndarray | None:
        """Per-VW keyed-state sizes (bytes), or None when state-size
        accounting is off. Assign an [V] array to seed it (e.g. from a
        ``VWStateMigrator``'s measured tree sizes); assigning None turns
        accounting back off."""
        return None if self._vw_bytes is None else self._vw_bytes.copy()

    @vw_state_bytes.setter
    def vw_state_bytes(self, value) -> None:
        if value is None:
            self._vw_bytes = None
            return
        value = np.asarray(value, np.float64)
        if value.shape != (self.n_virtual,):
            raise ValueError(f"vw_state_bytes must be [{self.n_virtual}]")
        self._vw_bytes = value.copy()

    @property
    def bytes_moved(self) -> float:
        """Cumulative VW state bytes migrated (rebalance + evacuation)."""
        return float(self._dstate.bytes_moved)

    def evacuate(self, replica: int, capacities=None) -> tuple[int, float]:
        """Shed *everything* the dead replica owns, capacity-
        proportionally onto the survivors — the capacity→0 limit of the
        delegation engine (``delegation.evacuate``), not round-robin.
        Unmetered: byte budgets never gate an evacuation (the transfer
        is mandatory), bytes are only accounted. Returns
        ``(n_moved, bytes_moved)``."""
        caps = (np.ones(self.n_replicas, np.float64) if capacities is None
                else np.asarray(capacities, np.float64))
        new_owner, n_moved, nbytes = delegation.evacuate(
            np.asarray(self._dstate.vw_owner),
            np.asarray(self._dstate.vw_rate), replica, caps,
            vw_bytes=self._vw_bytes)
        if n_moved:
            self._dstate = self._dstate._replace(
                vw_owner=jnp.asarray(new_owner, jnp.int32),
                moves=self._dstate.moves + jnp.int32(n_moved),
                bytes_moved=self._dstate.bytes_moved + jnp.float32(nbytes))
            self.moves += n_moved
            self._note_owner_update(force=True)
        return n_moved, nbytes

    @property
    def vw_load(self) -> np.ndarray:
        """Merged per-VW load (base + unpublished lane deltas), as a
        fresh NumPy array — a device download, for monitoring/rebalance.
        Assigning to it reseeds the base load and clears the deltas."""
        s = self._state
        return np.asarray(s.base + s.delta.sum(0))

    @vw_load.setter
    def vw_load(self, value) -> None:
        value = np.asarray(value, np.float32)
        self._state = self._state._replace(
            base=jnp.asarray(value),
            delta=jnp.zeros_like(self._state.delta))
        # a reseeded load is a restore: seed the delegation rates with
        # it (cumulative mode keeps rate == load; windowed mode starts
        # its window from the restored distribution) and realign the
        # tracker so the next rebalance sees zero phantom arrivals.
        self._rated_load = jnp.asarray(value)
        self._dstate = self._dstate._replace(
            vw_rate=jnp.asarray(value))
        # conservation invariant: routed == total load. Re-deriving it
        # here keeps the host-side rebase trigger sound after a state
        # restore that only seeds the loads; assign ``routed`` after
        # this to override the clock explicitly.
        self.routed = int(value.sum())
        if self._policy is not None:
            # a load restore carries no key frequencies: rescale the
            # carried sketch so its mass matches the restored clock and
            # the est/mass heavy classification stays calibrated
            mass = float(self._state.sketch_base.sum()) / max(
                self._policy.depth, 1)
            f = jnp.float32(self._routed / max(mass, 1.0))
            self._state = self._state._replace(
                sketch_base=self._state.sketch_base * f,
                sketch_delta=jnp.zeros_like(self._state.sketch_delta))

    @property
    def routed(self) -> int:
        return self._routed

    @routed.setter
    def routed(self, value) -> None:
        self._routed = int(value)
        # the adaptive controller's traffic mark must never sit ahead of
        # the clock (routed - mark would go negative after a restore)
        self._rebalance_mark = min(self._rebalance_mark, self._routed)
        self._state = self._state._replace(routed=jnp.float32(self._routed))

    def _maybe_rebase(self) -> None:
        # The engine carries load/routed as f32: past 2^24 a +1.0 becomes
        # a silent no-op and balancing would collapse onto "frozen" VWs.
        # Rebase by the min load first (shifts the capacity check by only
        # eps·base, and keeps every counter far from the f32 ceiling).
        # The trigger is the (1+eps)·m/n envelope plus the staleness
        # bound — a host-side bound on the true max load, so the hot
        # path never waits on a device readback.
        stale = max(self.block_size, 1) * self.n_sources * self.sync_every
        if (1.0 + self.eps) * self._routed / self.n_virtual + stale < 2 ** 23:
            return
        old_routed = self._routed
        shift = float(jnp.min(self._state.base + self._state.delta.sum(0)))
        self._routed -= int(shift * self.n_virtual)
        self._rebalance_mark -= int(shift * self.n_virtual)
        self._state = self._state._replace(
            base=self._state.base - shift,
            routed=jnp.float32(self._routed))
        self._rated_load = self._rated_load - shift   # keep deltas exact
        if self._policy is not None and old_routed > 0:
            # the sketch counts absolute messages and would hit the same
            # f32 +1.0 ceiling; scale it with the clock so the est/mass
            # heavy classification is unchanged
            f = jnp.float32(self._routed / old_routed)
            self._state = self._state._replace(
                sketch_base=self._state.sketch_base * f,
                sketch_delta=self._state.sketch_delta * f)

    def route(self, key: int) -> int:
        """PoRC over virtual replicas (Alg. 1), then owner lookup.

        Pure-python sequential oracle — ``route_batch`` with
        ``block_size=1`` is bit-identical to a sequence of these calls.
        Lane deltas are flushed first (a forced sync), so the probe
        chain sees the true global load. With a heavy-hitter policy the
        oracle doesn't exist (probe budgets are sketch-defined), so the
        request routes through the batch path as a block of one.
        """
        if self._policy is not None:
            return int(self.route_batch(np.asarray([key], np.int32))[0])
        self._maybe_rebase()
        if self.n_sources > 1 or self.sync_every > 1:
            state = multisource_merge(self._state)    # flush lane deltas
        else:
            state = self._state                       # deltas provably empty
        load = np.array(state.base)                   # writable host copy
        self._routed += 1
        cap = (1.0 + self.eps) * self._routed / self.n_virtual
        salt = 1
        vw = int(hash_to_bins(jnp.int32(key), salt, self.n_virtual))
        while load[vw] >= cap and salt < 4 * self.n_virtual:
            salt += 1
            vw = int(hash_to_bins(jnp.int32(key), salt, self.n_virtual))
        if load[vw] >= cap:
            vw = int(np.argmin(load))
        load[vw] += 1
        if self._vw_bytes is not None and self.state_bytes_per_request > 0:
            self._vw_bytes[vw] += self.state_bytes_per_request
        self._state = state._replace(
            base=jnp.asarray(load, jnp.float32),
            routed=jnp.float32(self._routed))
        return int(self._owner_view()[vw])

    def dispatch_batch(self, keys: np.ndarray):
        """Routing half of the submit path: launch the PoRC assignment
        on device and return the (still possibly in-flight) VW
        assignment array without forcing a host sync — the async submit
        path overlaps this with serving. ``finalize_batch`` turns the
        handle into replica ids."""
        keys = np.asarray(keys, np.int32)
        self._maybe_rebase()
        from repro.kernels import resolve_engine
        assign_vw, self._state = ref_porc_multisource(
            jnp.asarray(keys), self.n_virtual, self.n_sources,
            sync_every=self.sync_every, block=self.block_size,
            eps=self.eps, state=self._state, policy=self._policy,
            engine=resolve_engine(self.engine))
        self._routed += len(keys)
        return assign_vw

    def finalize_batch(self, assign_vw) -> np.ndarray:
        """Admission half: bind a dispatched VW assignment to replicas
        through the (possibly versioned) owner view and settle the
        per-VW state-byte accrual. This is where the host blocks on the
        device result."""
        if self._vw_bytes is not None and self.state_bytes_per_request > 0:
            # keyed session state grows where the requests land
            self._vw_bytes += self.state_bytes_per_request * np.bincount(
                np.asarray(assign_vw).ravel(), minlength=self.n_virtual)
        # owner gather on device — the owner map never leaves it
        return np.asarray(jnp.take(self._owner_view(),
                                   jnp.asarray(assign_vw)))

    def route_batch(self, keys: np.ndarray) -> np.ndarray:
        """Sharded block-parallel PoRC over virtual replicas (the
        default submit path). The batch splits round-robin across
        ``n_sources`` lanes routed concurrently (vmapped); load state
        stays device-resident across calls. A trailing partial block
        routes as power-of-two sub-blocks, so no padding keys ever
        pollute the load state and arbitrary batch sizes compile only
        O(log block_size) remainder programs."""
        return self.finalize_batch(self.dispatch_batch(keys))

    def rebalance(self, busy: list[int], idle: list[int],
                  pressure=None, capacities=None, depths=None) -> int:
        """Paired moves through the shared delegation engine.

        Busy replicas pair with idle ones in severity order (``pressure``
        — e.g. queue occupancy; higher = more overloaded) with FCFS
        carry-over across calls; without ``pressure`` the list order of
        ``busy``/``idle`` is taken as the severity order, which keeps
        the legacy call signature working. One jitted call updates the
        device-resident owner map, rates and queues — no per-VW host
        loop. ``capacities`` (any scale) drives capacity-proportional
        budgets when the router is ``capacity_weighted``.

        With the adaptive controller on (``adaptive_moves`` or
        ``hysteresis``) and ``pressure`` given, the busy/idle masks are
        re-derived from the controller's latched signals (the raw lists
        only matter as a legacy fallback) and the per-tick move budget
        comes from the EWMA'd ``depths`` (queue lengths, in messages;
        defaults to ``pressure · max_queue``), clamped to
        ``[min_moves, max_moves_per_rebalance]``.
        """
        n = self.n_replicas
        budget = None
        if self._controller is not None and pressure is None:
            # silently falling back to the legacy path would strand the
            # controller: latches/EWMA never tick, flap telemetry stays
            # 0, and the routed-traffic mark drifts so a later adaptive
            # budget is computed against an inflated unit
            raise ValueError(
                "adaptive_moves/hysteresis require rebalance(pressure=...)"
                " (e.g. queue occupancy) so the controller can tick")
        if self._controller is not None:
            p = np.asarray(pressure, np.float32)
            # pressure on this router is occupancy (a fraction of
            # max_queue — queue_hi/queue_lo compare against it), but the
            # budget needs backlog in *message* units to match ``unit``;
            # a raw-fraction fallback would pin the budget at min_moves
            d = (p * self.max_queue if depths is None
                 else np.asarray(depths, np.float32))
            # one VW re-routes ~1/V of the traffic since the last tick
            unit = max((self._routed - self._rebalance_mark)
                       / max(self.n_virtual, 1), 1.0)
            self._rebalance_mark = self._routed
            ub = (None if self._vw_bytes is None
                  else max(float(self._vw_bytes.mean()), 1.0))
            busy_j, idle_j, budget_j = self._controller.step(
                p, d, unit, unit_bytes=ub)
            busy_mask, idle_mask = np.asarray(busy_j), np.asarray(idle_j)
            budget = budget_j if self.adaptive_moves else None
            if (not busy_mask.any() and not self._queued_busy) or (
                    not idle_mask.any() and not self._queued_idle):
                return 0
        else:
            # carried FCFS signals count as candidates: a busy replica
            # left queued by an earlier budget must still pair when only
            # the idle side shows up this tick (and vice versa)
            if ((not len(busy) and not self._queued_busy)
                    or (not len(idle) and not self._queued_idle)):
                return 0
            if pressure is None:
                p = np.zeros(n, np.float32)
                for j, b in enumerate(busy):
                    p[b] = 1e6 - j      # earlier in the list = more severe
                for j, i in enumerate(idle):
                    p[i] = -1e6 + j     # earlier in the list = more idle
            else:
                p = np.asarray(pressure, np.float32)
            busy_mask = np.zeros(n, bool)
            busy_mask[list(busy)] = True
            idle_mask = np.zeros(n, bool)
            idle_mask[list(idle)] = True
        load = self._state.base + self._state.delta.sum(0)   # device
        caps = (jnp.ones(n, jnp.float32) if capacities is None
                else jnp.asarray(capacities, jnp.float32))
        vb = (None if self._vw_bytes is None
              else jnp.asarray(self._vw_bytes, jnp.float32))
        self._dstate, moved = delegation.rebalance_step(
            self._dcfg, self._dstate, jnp.asarray(p),
            jnp.asarray(busy_mask), jnp.asarray(idle_mask),
            load - self._rated_load, caps, budget, vb)
        self._rated_load = load
        if int(moved):
            self._note_owner_update()
        q = self._dstate.queues
        self._queued_busy = bool(jnp.any(q.busy_since != delegation.NOT_QUEUED))
        self._queued_idle = bool(jnp.any(q.idle_since != delegation.NOT_QUEUED))
        moved = int(moved)
        self.moves += moved
        return moved


class ServingEngine:
    """Queue-per-replica engine. ``replica_fns`` map a batch of request
    payloads to outputs; service speed differences model heterogeneity.

    Failure awareness (all knobs default off = bit-identical to the
    failure-oblivious engine):

    * **Liveness.** Replicas heartbeat every tick while their process is
      up (``ReplicaState.alive``); with ``heartbeat_timeout_steps > 0``
      a replica whose heartbeat is that many ticks stale is *declared*
      dead by the monitor — until then requests keep landing on its
      queue (the detection window the failure benchmarks measure). With
      the timeout at 0, an injected crash is declared the same tick.
    * **Evacuation.** Declaring a replica dead sheds all its virtual
      replicas capacity-proportionally onto survivors through the
      shared delegation engine (``router.evacuate`` — capacity→0, not
      round-robin) and re-routes every request stranded on its queue.
    * **At-least-once retries.** Stranded requests go to a retry queue
      with exponential backoff (``retry_backoff_steps · 2^attempts``
      ticks, capped) and re-route through the normal submit path with
      their *original* submit time but a *fresh* head-of-line timeout
      window (``request_timeout_steps`` measures from the last
      re-enqueue) — nothing is ever silently dropped:
      ``submitted == served + in_flight`` at every tick (``dropped``
      exists only to pin that contract at 0).
    * **Re-admission ramp.** A recovered replica re-enters with its
      effective capacity scaled by ``readmit_floor`` ramping linearly to
      1 over ``readmit_ramp_steps`` ticks, so the capacity-weighted
      budgets hand its share back gradually instead of flapping the
      owner map.
    * **Chaos.** ``chaos`` is any object with
      ``pop_due(step) -> events`` (``repro.runtime.chaos``): "crash"
      calls :meth:`fail_replica`, "slow" divides the replica's drain
      rate, "recover" calls :meth:`recover_replica`.
    * **Stateful migration.** ``migrator`` (e.g.
      ``repro.runtime.fault_tolerance.VWStateMigrator``) receives a
      ``transfer(vw, src, dst)`` call for every owner-map change —
      rebalance and evacuation share that one migration path.
    * **Async submit.** ``async_submit=True`` splits the submit path:
      ``submit_batch`` only *dispatches* the sharded routing on device
      (``router.dispatch_batch``) and parks the handle; the next
      ``step`` *admits* it (``finalize_batch`` + enqueue) after chaos
      and liveness have run — so routing of tick t+1's traffic overlaps
      tick t's replica drain. Pending dispatches count as ``in_flight``
      and an admission that lands on a declared-dead replica goes to
      the retry queue, so ``submitted == served + in_flight`` holds at
      every tick boundary, async or not. Off = the synchronous
      route-then-enqueue path, bit-identical to before.
    * **Capacity-estimate hysteresis.**
      ``capacity_enter_margin``/``capacity_exit_margin`` latch the
      served-per-tick capacity EWMA the way the controller latches
      busy/idle: the estimate only starts tracking when a saturated
      tick deviates from it by more than the enter margin
      (relative), then keeps tracking until it re-converges within the
      exit margin. A recovering replica's one-off hiccup no longer
      flaps its capacity share; a real speed change is tracked to
      convergence. Margins at 0 (default) = plain per-tick EWMA.
    """

    def __init__(self, replica_fns, router: CGRequestRouter | None = None,
                 max_batch: int = 8, *, chaos=None,
                 heartbeat_timeout_steps: int = 0,
                 retry_backoff_steps: int = 1,
                 max_retry_backoff_steps: int = 8,
                 request_timeout_steps: int = 0,
                 readmit_ramp_steps: int = 0,
                 readmit_floor: float = 0.05,
                 migrator=None,
                 async_submit: bool = False,
                 capacity_enter_margin: float = 0.0,
                 capacity_exit_margin: float = 0.0):
        n = len(replica_fns)
        self.replicas = [ReplicaState() for _ in replica_fns]
        self.fns = list(replica_fns)
        self.router = router or CGRequestRouter(n)
        self.max_batch = max_batch
        self.latencies: list[float] = []
        self.latency_steps: list[int] = []   # tick-latency of each served
                                             # request (deterministic)
        # per-replica capacity estimate from served/queue telemetry
        # (EWMA of requests actually drained per tick while there was
        # work) — what the delegation engine's capacity-weighted
        # budgets consume; replicas never reveal capacities directly.
        self.capacity_estimates = np.full(len(self.fns), float(max_batch))
        # -- failure-awareness state --
        self.chaos = chaos
        self.heartbeat_timeout_steps = heartbeat_timeout_steps
        self.retry_backoff_steps = retry_backoff_steps
        self.max_retry_backoff_steps = max_retry_backoff_steps
        self.request_timeout_steps = request_timeout_steps
        self.readmit_ramp_steps = readmit_ramp_steps
        self.readmit_floor = readmit_floor
        self.migrator = migrator
        self.async_submit = async_submit
        # (dispatch handle, keys, payloads, submit time, submit tick)
        self._pending: list[tuple] = []
        self.capacity_enter_margin = capacity_enter_margin
        self.capacity_exit_margin = capacity_exit_margin
        self._cap_latched = np.zeros(n, bool)
        self.step_idx = 0
        self.submitted = 0
        self.retried = 0
        self.dropped = 0              # the at-least-once contract: 0
        self.evacuations = 0
        self.failures: list[tuple[int, int]] = []   # (step, replica)
        self._retry: deque[tuple[int, Request]] = deque()  # (ready, req)
        self._dead = np.zeros(n, bool)       # declared by the monitor
        self._beating = np.ones(n, bool)
        self._last_beat = np.zeros(n, np.int64)
        self._readmit = np.ones(n, np.float64)

    # -- request intake ---------------------------------------------------
    def submit(self, key: int, payload) -> None:
        """Single-request submit — routed through the batch path (a
        batch of one is one block of one, i.e. exact Alg. 1)."""
        self.submit_batch(np.asarray([key], np.int32), [payload])

    def submit_batch(self, keys: np.ndarray, payloads) -> None:
        keys = np.asarray(keys, np.int32)
        if self.async_submit:
            # dispatch only — the device routes while the host keeps
            # going; the next step() admits the result
            handle = self.router.dispatch_batch(keys)
            self.submitted += len(keys)
            self._pending.append((handle, keys, list(payloads),
                                  time.monotonic(), self.step_idx))
            return
        assign = self.router.route_batch(keys)
        now = time.monotonic()
        self.submitted += len(keys)
        for r, k, p in zip(assign, keys, payloads):
            self.replicas[int(r)].queue.append(
                Request(now, self.step_idx, int(k), p, enq=self.step_idx))

    def _admit_pending(self) -> None:
        """Admission half of the async submit path: bind every parked
        dispatch to replicas through the router's current owner view
        and enqueue. Runs after chaos + liveness so an assignment whose
        target was just declared dead goes straight to the retry queue
        instead of a corpse."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        for handle, keys, payloads, t0, tick in pending:
            assign = self.router.finalize_batch(handle)
            for a, k, p in zip(assign, keys, payloads):
                req = Request(t0, tick, int(k), p, enq=self.step_idx)
                rep = self.replicas[int(a)]
                if rep.alive or not self._dead[int(a)]:
                    rep.queue.append(req)
                else:
                    self._schedule_retry(req)
                    self.retried += 1

    @property
    def in_flight(self) -> int:
        """Requests accepted but not yet served (replica queues, the
        retry queue and pending async dispatches).
        ``submitted == served + in_flight`` always."""
        return (sum(len(r.queue) for r in self.replicas) + len(self._retry)
                + sum(len(p[1]) for p in self._pending))

    # -- failure / recovery ----------------------------------------------
    def fail_replica(self, i: int) -> None:
        """Crash-stop replica ``i``: it stops serving and heartbeating
        *now*; the monitor declares it dead (evacuation + re-routes)
        immediately, or after ``heartbeat_timeout_steps`` stale ticks
        when heartbeat detection is on."""
        rep = self.replicas[i]
        if not rep.alive:
            return
        rep.alive = False
        self._beating[i] = False
        self.failures.append((self.step_idx, i))
        if self.heartbeat_timeout_steps <= 0:
            self._declare_dead(i)

    def recover_replica(self, i: int) -> None:
        """Replica ``i``'s process returns: heartbeats resume and, if it
        had been declared dead, its capacity re-admits through the ramp
        (it owns no virtual replicas until delegation hands some back)."""
        rep = self.replicas[i]
        rep.alive = True
        rep.slow_factor = 1.0
        self._beating[i] = True
        self._last_beat[i] = self.step_idx
        was_declared = bool(self._dead[i])
        self._dead[i] = False
        if was_declared and self.readmit_ramp_steps > 0:
            self._readmit[i] = self.readmit_floor

    def _declare_dead(self, i: int) -> None:
        """Monitor verdict: evacuate VWs through the delegation engine
        and re-route every request stranded on the dead queue."""
        if self._dead[i]:
            return
        self._dead[i] = True
        rep = self.replicas[i]
        stranded = len(rep.queue)
        while rep.queue:
            self._schedule_retry(rep.queue.popleft())
        self.retried += stranded
        before = (self.router.vw_owner if self.migrator is not None
                  else None)
        self.router.evacuate(i, self._effective_capacities())
        self._migrate_owner_changes(before)
        self.evacuations += 1

    def _schedule_retry(self, req: Request) -> None:
        """Exponential backoff, capped; the request keeps its original
        submit time/tick so failure cost shows up as latency, and its
        attempt count so repeated failures back off harder. Never drops."""
        back = min(self.retry_backoff_steps * (2 ** req.attempts),
                   self.max_retry_backoff_steps)
        self._retry.append((self.step_idx + max(int(back), 1),
                            req._replace(attempts=req.attempts + 1)))

    def _drain_retries(self) -> None:
        ready = [r for t, r in self._retry if t <= self.step_idx]
        if not ready:
            return
        self._retry = deque((t, r) for t, r in self._retry
                            if t > self.step_idx)
        assign = self.router.route_batch(
            np.asarray([r.key for r in ready], np.int32))
        for a, req in zip(assign, ready):
            rep = self.replicas[int(a)]
            if rep.alive or not self._dead[int(a)]:
                rep.queue.append(req._replace(enq=self.step_idx))
            else:
                self._schedule_retry(req)    # landed on a corpse: back off
                self.retried += 1

    def _effective_capacities(self) -> np.ndarray:
        """The capacity estimates the delegation engine sees: declared-
        dead replicas collapse to ~0 (they shed everything), recovering
        ones re-admit through the ramp. With everyone alive and ramped
        this is exactly the raw estimate (defaults-off parity)."""
        eff = np.maximum(self.capacity_estimates, 1e-3) * self._readmit
        eff[self._dead] = 1e-3
        return eff

    def _check_liveness(self) -> None:
        if self.heartbeat_timeout_steps <= 0:
            return
        for i in range(len(self.replicas)):
            if self._beating[i]:
                self._last_beat[i] = self.step_idx
            elif (not self._dead[i] and self.step_idx - self._last_beat[i]
                    >= self.heartbeat_timeout_steps):
                self._declare_dead(i)

    def _migrate_owner_changes(self, before: np.ndarray | None) -> None:
        if self.migrator is None or before is None:
            return
        after = self.router.vw_owner
        for v in np.flatnonzero(before != after):
            self.migrator.transfer(int(v), int(before[v]), int(after[v]))

    def apply_chaos(self, ev) -> None:
        if ev.kind == "crash":
            self.fail_replica(ev.replica)
        elif ev.kind == "slow":
            self.replicas[ev.replica].slow_factor = float(ev.factor)
        elif ev.kind == "recover":
            self.recover_replica(ev.replica)
        else:
            raise ValueError(f"unknown chaos event kind {ev.kind!r}")

    # -- the engine tick ---------------------------------------------------
    def step(self) -> int:
        """One engine tick: chaos events fire, the liveness monitor
        runs, due retries re-route, each live replica serves up to
        max_batch requests, then delegation signals fire and the router
        re-pairs busy↔idle in severity order (most-overloaded with
        most-idle, §V-B) using queue occupancy as the pressure signal."""
        self.step_idx += 1
        if self.chaos is not None:
            for ev in self.chaos.pop_due(self.step_idx):
                self.apply_chaos(ev)
        self._check_liveness()
        self._admit_pending()
        self._drain_retries()
        served = 0
        now = time.monotonic()
        occupancy = np.zeros(len(self.replicas), np.float32)
        for i, (rep, fn) in enumerate(zip(self.replicas, self.fns)):
            if not rep.alive:
                # a crashed process serves nothing; once declared dead
                # it reads as full pressure *while it still owns VWs*
                # (evacuation can span slots under a byte budget) so it
                # keeps shedding. Once stripped it exerts neutral
                # pressure — between the idle and busy bands — so it
                # neither clogs the busy queue with no-op shed attempts
                # nor latches idle and absorbs VWs back.
                if self._dead[i]:
                    owns = bool((np.asarray(self.router.vw_owner)
                                 == i).any())
                    occupancy[i] = (1.0 if owns else 0.5 * (
                        self.router.queue_lo + self.router.queue_hi))
                    rep.busy_signal = owns
                else:
                    occupancy[i] = len(rep.queue) / self.router.max_queue
                    rep.busy_signal = occupancy[i] > self.router.queue_hi
                rep.idle_signal = False
                continue
            # head-of-line timeout measures from the last (re-)enqueue,
            # not the original submit — a retried request must get a
            # fresh window on its new replica or it would time out again
            # at every queue head forever (a drain-less livelock)
            if self.request_timeout_steps > 0:
                while rep.queue and (self.step_idx - rep.queue[0].enq
                                     > self.request_timeout_steps):
                    self._schedule_retry(rep.queue.popleft())
                    self.retried += 1
            had_work = bool(rep.queue)
            cap = max(1, int(round(self.max_batch / max(rep.slow_factor,
                                                        1e-9))))
            batch = []
            while rep.queue and len(batch) < cap:
                batch.append(rep.queue.popleft())
            if batch:
                fn([r.payload for r in batch])
                now = time.monotonic()
                self.latencies.extend(now - r.t for r in batch)
                self.latency_steps.extend(self.step_idx - r.step
                                          for r in batch)
                rep.served += len(batch)
                served += len(batch)
            # only *saturated* ticks reveal capacity: a full batch, or a
            # queue still backed up after serving, means the replica
            # drained at its limit. A partial batch that empties the
            # queue measures demand, not capacity — folding it in would
            # rank a fast lightly-loaded replica *below* an overloaded
            # one and invert the capacity-weighted budgets.
            if had_work and (len(batch) == cap or rep.queue):
                est = self.capacity_estimates[i]
                obs = float(len(batch))
                if self.capacity_enter_margin > 0:
                    # hysteresis latch (mirrors the controller's
                    # busy/idle latch): a saturated tick must deviate
                    # past the enter margin to engage tracking; once
                    # engaged the EWMA runs until the estimate
                    # re-converges within the exit margin
                    if (not self._cap_latched[i]
                            and abs(obs - est) / max(est, 1e-9)
                            > self.capacity_enter_margin):
                        self._cap_latched[i] = True
                    if self._cap_latched[i]:
                        est = 0.7 * est + 0.3 * obs
                        self.capacity_estimates[i] = est
                        if (abs(obs - est) / max(est, 1e-9)
                                < self.capacity_exit_margin):
                            self._cap_latched[i] = False
                else:
                    self.capacity_estimates[i] = 0.7 * est + 0.3 * obs
            occ = len(rep.queue) / self.router.max_queue
            occupancy[i] = occ
            rep.busy_signal = occ > self.router.queue_hi
            rep.idle_signal = occ < self.router.queue_lo
        # re-admission ramp: recovered replicas earn their share back
        below = self._readmit < 1.0
        if below.any() and self.readmit_ramp_steps > 0:
            alive = np.asarray([r.alive for r in self.replicas])
            self._readmit[below & alive] = np.minimum(
                1.0, self._readmit[below & alive]
                + 1.0 / self.readmit_ramp_steps)
        busy = [i for i, r in enumerate(self.replicas) if r.busy_signal]
        idle = [i for i, r in enumerate(self.replicas) if r.idle_signal]
        # with the adaptive controller on, every tick must reach the
        # router so the hysteresis latches and depth EWMA stay current
        if busy or idle or self.router.controller_active:
            before = (self.router.vw_owner if self.migrator is not None
                      else None)
            self.router.rebalance(
                busy, idle, pressure=occupancy,
                capacities=self._effective_capacities(),
                depths=np.asarray(self.queue_depths(), np.float32))
            self._migrate_owner_changes(before)
        return served

    def queue_depths(self) -> list[int]:
        return [len(r.queue) for r in self.replicas]
