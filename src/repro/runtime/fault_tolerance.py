"""Fault tolerance: checkpoint/restart + elastic re-mesh via CG pairing.

Posture for 1000+ nodes (DESIGN.md §5):

* **Step-granular recovery.** The trainer checkpoints (params, opt
  state, data-pipeline cursor) every ``ckpt_every`` steps through the
  async checkpointer. On any worker failure the job restarts from the
  last committed step; pipeline shards are deterministically seeded so
  the stream suffix replays exactly (no message migration — the paper's
  consistency rule at step granularity).

* **Elastic re-mesh.** When a host is lost *between* checkpoints, its
  pipeline shards (virtual workers) are re-paired onto surviving hosts
  through the shared delegation engine (``delegation.plan_pairs`` — the
  same pairing the serving router and the straggler balancer use): the
  dead host raises a permanent busy signal, survivors are ranked idle
  by projected shards-per-capacity, and one paired move executes per
  planning round until the dead host owns nothing. Shards therefore
  land **capacity-proportionally** — a 3× host absorbs ~3× the shards —
  not round-robin. When the host pool changes durably, ``plan_remesh``
  picks the largest (data × model) mesh that fits the survivors and the
  checkpoint is resharded on load (restore is sharding-agnostic: leaves
  are host numpy arrays).

* **Failure detection** here is heartbeat-based (hosts report each
  step); on real fleets this is the TPU runtime's job — the interface
  (`on_failure`) is the part that matters. ``on_failure`` is the single
  dead-marking path: heartbeat expiry and direct calls take the same
  route and it is idempotent (a host already marked dead is not
  evacuated twice).

* **Stateful VW migration.** ``VWStateMigrator`` moves a virtual
  worker's keyed state through the atomic checkpointer: ``transfer``
  round-trips the state via a committed ``.tmp``→rename checkpoint, so
  a crash mid-migration can never corrupt it — re-mesh and rebalance
  share this one migration path (hand the migrator to
  ``ServingEngine(migrator=...)``).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.core import delegation

from .straggler import DelegationBalancer


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    heartbeat_timeout_s: float = 300.0
    max_keep: int = 3


@dataclass
class HostState:
    last_heartbeat: float = 0.0
    alive: bool = True


class FaultTolerantRunner:
    """Wraps a train loop with checkpoint/restart + elastic response.

    ``capacities`` (optional [n_hosts] floats) are the service-rate
    estimates the evacuation planner weighs survivors by; None means
    uniform (shards spread evenly, the pre-capacity behaviour — but
    still deficit-ranked, not round-robin).
    """

    def __init__(self, cfg: FTConfig, n_hosts: int, pipeline=None,
                 capacities=None):
        self.cfg = cfg
        self.hosts = [HostState(time.monotonic()) for _ in range(n_hosts)]
        self.pipeline = pipeline
        self.capacities = (np.ones(n_hosts) if capacities is None
                           else np.asarray(capacities, np.float64))
        self.balancer = DelegationBalancer(n_hosts)
        self.saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.max_keep)
        self.failures: list[tuple[float, int]] = []
        # pairing-only delegation config for evacuation planning: one
        # move per planning round (loads are re-projected after every
        # shard lands), no FCFS carry-over (each round is a fresh plan)
        self._evac_cfg = delegation.DelegationConfig(
            n_workers=n_hosts, n_virtual=0, max_moves_per_slot=1)

    # -- liveness ---------------------------------------------------------
    def heartbeat(self, host: int) -> None:
        self.hosts[host].last_heartbeat = time.monotonic()

    def check_failures(self, timeout_s: float | None = None) -> list[int]:
        """Declare hosts whose heartbeat is older than ``timeout_s``
        (default: the config's) dead. Marking + evacuation happen in
        ``on_failure`` — the one path both detection routes share."""
        timeout = (self.cfg.heartbeat_timeout_s if timeout_s is None
                   else timeout_s)
        now = time.monotonic()
        dead = [i for i, h in enumerate(self.hosts)
                if h.alive and now - h.last_heartbeat > timeout]
        for d in dead:
            self.on_failure(d)
        return dead

    def on_failure(self, host: int) -> list[tuple[int, int]]:
        """Elastic response: re-pair the dead host's virtual shards onto
        surviving hosts through ``delegation.plan_pairs`` (removal paired
        with addition), capacity-proportionally. Idempotent — a host
        already marked dead returns [] without re-evacuating."""
        if not self.hosts[host].alive:
            return []
        self.hosts[host].alive = False
        self.failures.append((time.monotonic(), host))
        moved: list[tuple[int, int]] = []
        if self.pipeline is None:
            return moved
        alive = np.asarray([h.alive for h in self.hosts])
        if not alive.any():
            return moved
        caps = np.where(alive, np.maximum(self.capacities, 1e-9), 1e-9)
        queues = delegation.init_queues(len(self.hosts))
        # only the host being evacuated signals busy (earlier casualties
        # already shed their shards); every survivor signals idle and the
        # planner picks the least-pressured one each round
        busy = np.zeros(len(self.hosts), bool)
        busy[host] = True
        while True:
            counts = np.bincount(self.pipeline.shard_owner,
                                 minlength=len(self.hosts)).astype(float)
            # the dead host reads as infinitely pressured (it must shed
            # everything); survivors rank idle by projected load share,
            # so each shard lands on the largest remaining deficit
            pressure = np.where(alive, counts / caps, 1e9)
            src, dst, n_exec, queues = delegation.plan_pairs(
                self._evac_cfg, queues, pressure, busy, alive)
            if int(n_exec) == 0:
                break
            sid = self.pipeline.move_shard(int(src[0]), int(dst[0]))
            if sid is None:
                break
            moved.append((sid, int(dst[0])))
        return moved

    # -- checkpointing ----------------------------------------------------
    def maybe_save(self, step: int, tree) -> bool:
        if step % self.cfg.ckpt_every != 0:
            return False
        self.saver.save(step, tree)
        return True

    def restore_latest(self, like):
        """(step, tree) from the last committed checkpoint, or (0, None)."""
        s = ckpt.latest_step(self.cfg.ckpt_dir)
        if s is None:
            return 0, None
        return s, ckpt.restore(self.cfg.ckpt_dir, s, like)


class VWStateMigrator:
    """Per-VW state transfer through the atomic checkpointer.

    Each virtual worker's keyed state (session maps, KV-cache pages)
    lives under ``<root>/vw_<id>/`` as a versioned checkpoint; ``put``
    commits a new version (``.tmp``→rename, crash-safe) and ``transfer``
    performs the migration a rebalance or evacuation decided: the
    committed bytes are re-read at the destination — the round-trip
    *is* the state movement, and its cost is what
    ``DelegationConfig.byte_budget_per_slot`` meters.

    ``bytes_moved``/``transfers`` are the accounting the failure
    benchmarks read; ``state_bytes`` feeds the router's per-VW byte
    accounting (``CGRequestRouter.vw_state_bytes``).
    """

    def __init__(self, root_dir: str):
        self.root = root_dir
        self._version: dict[int, int] = {}
        self._nbytes: dict[int, float] = {}
        self._treedef: dict[int, object] = {}   # last put() tree structure
        self.transfers: list[tuple[int, int, int]] = []   # (vw, src, dst)
        self.bytes_moved = 0.0

    def _dir(self, vw: int) -> str:
        return os.path.join(self.root, f"vw_{vw}")

    @staticmethod
    def _tree_bytes(tree) -> float:
        return float(sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree)))

    def put(self, vw: int, tree) -> None:
        """Commit a new version of ``vw``'s state (atomic)."""
        v = self._version.get(vw, 0) + 1
        ckpt.save(self._dir(vw), v, tree, max_keep=2)
        self._version[vw] = v
        self._nbytes[vw] = self._tree_bytes(tree)
        self._treedef[vw] = jax.tree.structure(tree)

    def get(self, vw: int, like=None):
        """Latest committed state of ``vw`` (None if never put). ``like``
        defaults to the structure of the last tree ``put`` for this VW —
        a dict/nested tree comes back as that tree, not a flat leaf
        list; only a process that never ``put`` this VW (and passes no
        ``like``) gets the leaves in manifest order."""
        v = ckpt.latest_step(self._dir(vw))
        if v is None:
            return None
        if like is None:
            leaves = ckpt.restore(self._dir(vw), v,
                                  self._like_from_manifest(vw, v))
            td = self._treedef.get(vw)
            if td is not None and td.num_leaves == len(leaves):
                return jax.tree.unflatten(td, leaves)
            return leaves
        return ckpt.restore(self._dir(vw), v, like)

    def _like_from_manifest(self, vw: int, v: int):
        import json
        d = os.path.join(self._dir(vw), f"step_{v:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            m = json.load(f)
        return [np.zeros(s, np.dtype(t))
                for s, t in zip(m["shapes"], m["dtypes"])]

    def state_bytes(self, vw: int) -> float:
        return self._nbytes.get(vw, 0.0)

    def transfer(self, vw: int, src: int, dst: int) -> float:
        """Move ``vw``'s state from ``src`` to ``dst``: re-commit the
        latest version through the atomic path and account the bytes.
        A VW with no state is a free (stateless) move."""
        v = ckpt.latest_step(self._dir(vw))
        moved = 0.0
        if v is not None:
            tree = self.get(vw)
            self.put(vw, tree)          # destination's committed copy
            moved = self._nbytes.get(vw, 0.0)
            self.bytes_moved += moved
        self.transfers.append((vw, src, dst))
        return moved


def plan_remesh(n_alive_chips: int, model_parallel: int = 16) -> tuple[int, int]:
    """Largest (data, model) mesh fitting the surviving chips, keeping
    the model-parallel degree fixed (param resharding is the expensive
    axis; data-parallel degree is elastic)."""
    data = max(1, n_alive_chips // model_parallel)
    return data, model_parallel
