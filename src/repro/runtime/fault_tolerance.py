"""Fault tolerance: checkpoint/restart + elastic re-mesh via CG pairing.

Posture for 1000+ nodes (DESIGN.md §5):

* **Step-granular recovery.** The trainer checkpoints (params, opt
  state, data-pipeline cursor) every ``ckpt_every`` steps through the
  async checkpointer. On any worker failure the job restarts from the
  last committed step; pipeline shards are deterministically seeded so
  the stream suffix replays exactly (no message migration — the paper's
  consistency rule at step granularity).

* **Elastic re-mesh.** When a host is lost *between* checkpoints, its
  pipeline shards (virtual workers) are re-paired onto surviving idle
  hosts using the CG FCFS queues — the global batch keeps flowing at
  reduced capacity instead of stalling the fleet. When the host pool
  changes durably, ``plan_remesh`` picks the largest (data × model)
  mesh that fits the survivors and the checkpoint is resharded on load
  (restore is sharding-agnostic: leaves are host numpy arrays).

* **Failure detection** here is heartbeat-based (hosts report each
  step); on real fleets this is the TPU runtime's job — the interface
  (`on_failure`) is the part that matters.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.checkpoint import checkpointer as ckpt

from .straggler import DelegationBalancer


@dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    heartbeat_timeout_s: float = 300.0
    max_keep: int = 3


@dataclass
class HostState:
    last_heartbeat: float = 0.0
    alive: bool = True


class FaultTolerantRunner:
    """Wraps a train loop with checkpoint/restart + elastic response."""

    def __init__(self, cfg: FTConfig, n_hosts: int, pipeline=None):
        self.cfg = cfg
        self.hosts = [HostState(time.monotonic()) for _ in range(n_hosts)]
        self.pipeline = pipeline
        self.balancer = DelegationBalancer(n_hosts)
        self.saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.max_keep)
        self.failures: list[tuple[float, int]] = []

    # -- liveness ---------------------------------------------------------
    def heartbeat(self, host: int) -> None:
        self.hosts[host].last_heartbeat = time.monotonic()

    def check_failures(self) -> list[int]:
        now = time.monotonic()
        dead = []
        for i, h in enumerate(self.hosts):
            if h.alive and now - h.last_heartbeat > self.cfg.heartbeat_timeout_s:
                h.alive = False
                dead.append(i)
        for d in dead:
            self.on_failure(d)
        return dead

    def on_failure(self, host: int) -> list[tuple[int, int]]:
        """Elastic response: re-pair the dead host's virtual shards onto
        surviving hosts (CG pairing — removal paired with addition)."""
        self.failures.append((time.monotonic(), host))
        self.hosts[host].alive = False
        moved = []
        if self.pipeline is not None:
            survivors = [i for i, h in enumerate(self.hosts) if h.alive]
            if survivors:
                i = 0
                while True:
                    dst = survivors[i % len(survivors)]
                    sid = self.pipeline.move_shard(host, dst)
                    if sid is None:
                        break
                    moved.append((sid, dst))
                    i += 1
        return moved

    # -- checkpointing ----------------------------------------------------
    def maybe_save(self, step: int, tree) -> bool:
        if step % self.cfg.ckpt_every != 0:
            return False
        self.saver.save(step, tree)
        return True

    def restore_latest(self, like):
        """(step, tree) from the last committed checkpoint, or (0, None)."""
        s = ckpt.latest_step(self.cfg.ckpt_dir)
        if s is None:
            return 0, None
        return s, ckpt.restore(self.cfg.ckpt_dir, s, like)


def plan_remesh(n_alive_chips: int, model_parallel: int = 16) -> tuple[int, int]:
    """Largest (data, model) mesh fitting the surviving chips, keeping
    the model-parallel degree fixed (param resharding is the expensive
    axis; data-parallel degree is elastic)."""
    data = max(1, n_alive_chips // model_parallel)
    return data, model_parallel
