"""Straggler mitigation = the paper's *worker delegation* at step scale.

Each data-parallel host monitors its own step time (the "worker
monitors its workload" of §V-C) and emits a **binary** signal — busy
(step time above θ_b × median) or idle (below θ_i × median). Signals
piggyback on the per-step metrics the trainer already collects (no
extra communication round — the paper's piggybacking).

Pairing is a thin adapter over the shared ``repro.core.delegation``
engine (the same FCFS-with-severity-order queues the CG simulator and
the serving router use): busy hosts pair with idle hosts in severity
order, signals the move budget could not serve carry over FCFS to the
next slot, and one pipeline shard (virtual worker) moves per pair;
routing changes affect only future batches.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import delegation


@dataclass
class StragglerConfig:
    theta_busy: float = 1.15     # step_time > θ_b × median → busy
    theta_idle: float = 0.90     # step_time < θ_i × median → idle
    window: int = 8              # time slot t0, in steps
    max_moves_per_slot: int = 2


@dataclass
class DelegationBalancer:
    """Source-side CG balancer for pipeline shards across hosts."""
    n_hosts: int
    cfg: StragglerConfig = field(default_factory=StragglerConfig)

    def __post_init__(self):
        self._hist: list[deque] = [deque(maxlen=self.cfg.window)
                                   for _ in range(self.n_hosts)]
        self._dcfg = delegation.DelegationConfig(
            n_workers=self.n_hosts, n_virtual=0,
            max_moves_per_slot=self.cfg.max_moves_per_slot, fcfs=True)
        self._queues = delegation.init_queues(self.n_hosts)
        self.moves: list[tuple[int, int]] = []

    def observe(self, host: int, step_time_s: float) -> None:
        self._hist[host].append(step_time_s)

    def _means(self) -> list[float]:
        return [np.mean(h) if h else np.nan for h in self._hist]

    def signals(self) -> tuple[list[int], list[int]]:
        """Binary delegation signals after the current slot."""
        means = self._means()
        med = np.nanmedian(means)
        busy, idle = [], []
        if not np.isfinite(med) or med <= 0:
            return busy, idle
        for h, m in enumerate(means):
            if not np.isfinite(m):
                continue
            if m > self.cfg.theta_busy * med:
                busy.append(h)
            elif m < self.cfg.theta_idle * med:
                idle.append(h)
        return busy, idle

    def rebalance(self, pipeline) -> list[tuple[int, int]]:
        """Pair busy→idle hosts (severity order, FCFS carry-over across
        slots, bounded per slot) and move one shard per pair.
        ``pipeline`` must expose move_shard()."""
        busy, idle = self.signals()
        means = np.asarray(self._means(), np.float32)
        busy_mask = np.zeros(self.n_hosts, bool)
        busy_mask[busy] = True
        idle_mask = np.zeros(self.n_hosts, bool)
        idle_mask[idle] = True
        pressure = np.where(np.isfinite(means), means, 0.0)
        src, dst, n_pairs, self._queues = delegation.plan_pairs(
            self._dcfg, self._queues, jnp.asarray(pressure),
            jnp.asarray(busy_mask), jnp.asarray(idle_mask))
        src, dst = np.asarray(src), np.asarray(dst)
        moved = []
        for j in range(int(n_pairs)):
            sid = pipeline.move_shard(int(src[j]), int(dst[j]))
            if sid is not None:
                moved.append((int(src[j]), int(dst[j])))
        self.moves.extend(moved)
        return moved
