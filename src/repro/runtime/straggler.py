"""Straggler mitigation = the paper's *worker delegation* at step scale.

Each data-parallel host monitors its own step time (the "worker
monitors its workload" of §V-C) and emits a **binary** signal — busy
(step time above θ_b × median) or idle (below θ_i × median). Signals
piggyback on the per-step metrics the trainer already collects (no
extra communication round — the paper's piggybacking). The balancer
pairs busy hosts with idle hosts FCFS and moves one pipeline shard
(virtual worker) per pair; routing changes affect only future batches.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerConfig:
    theta_busy: float = 1.15     # step_time > θ_b × median → busy
    theta_idle: float = 0.90     # step_time < θ_i × median → idle
    window: int = 8              # time slot t0, in steps
    max_moves_per_slot: int = 2


@dataclass
class DelegationBalancer:
    """Source-side CG balancer for pipeline shards across hosts."""
    n_hosts: int
    cfg: StragglerConfig = field(default_factory=StragglerConfig)

    def __post_init__(self):
        self._hist: list[deque] = [deque(maxlen=self.cfg.window)
                                   for _ in range(self.n_hosts)]
        self._busy_queue: deque = deque()   # FCFS (paper §V-B pairing)
        self._idle_queue: deque = deque()
        self.moves: list[tuple[int, int]] = []

    def observe(self, host: int, step_time_s: float) -> None:
        self._hist[host].append(step_time_s)

    def signals(self) -> tuple[list[int], list[int]]:
        """Binary delegation signals after the current slot."""
        means = [np.mean(h) if h else np.nan for h in self._hist]
        med = np.nanmedian(means)
        busy, idle = [], []
        if not np.isfinite(med) or med <= 0:
            return busy, idle
        for h, m in enumerate(means):
            if not np.isfinite(m):
                continue
            if m > self.cfg.theta_busy * med:
                busy.append(h)
            elif m < self.cfg.theta_idle * med:
                idle.append(h)
        return busy, idle

    def rebalance(self, pipeline) -> list[tuple[int, int]]:
        """Pair busy→idle hosts FCFS and move one shard per pair
        (bounded per slot). ``pipeline`` must expose move_shard()."""
        busy, idle = self.signals()
        for h in busy:
            if h not in self._busy_queue:
                self._busy_queue.append(h)
        for h in idle:
            if h not in self._idle_queue:
                self._idle_queue.append(h)
        moved = []
        for _ in range(self.cfg.max_moves_per_slot):
            if not self._busy_queue or not self._idle_queue:
                break
            src = self._busy_queue.popleft()
            dst = self._idle_queue.popleft()
            sid = pipeline.move_shard(src, dst)
            if sid is not None:
                moved.append((src, dst))
        self.moves.extend(moved)
        return moved
