"""Straggler mitigation = the paper's *worker delegation* at step scale.

Each data-parallel host monitors its own step time (the "worker
monitors its workload" of §V-C) and emits a **binary** signal — busy
(step time above θ_b × median) or idle (below θ_i × median). Signals
piggyback on the per-step metrics the trainer already collects (no
extra communication round — the paper's piggybacking).

Pairing is a thin adapter over the shared ``repro.core.delegation``
engine (the same FCFS-with-severity-order queues the CG simulator and
the serving router use): busy hosts pair with idle hosts in severity
order, signals the move budget could not serve carry over FCFS to the
next slot, and one pipeline shard (virtual worker) moves per pair;
routing changes affect only future batches.

``StragglerConfig.hysteresis``/``adaptive_moves`` opt into the shared
adaptive controller (``repro.core.controller``): signals latch between
separate enter/exit step-time ratios with a dwell (a host hovering at
θ_b × median stops flapping), and the per-slot move budget follows the
summed step-time excess instead of the static ``max_moves_per_slot``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import controller, delegation


@dataclass
class StragglerConfig:
    theta_busy: float = 1.15     # step_time > θ_b × median → busy
    theta_idle: float = 0.90     # step_time < θ_i × median → idle
    window: int = 8              # time slot t0, in steps
    max_moves_per_slot: int = 2
    adaptive_moves: bool = False  # per-slot budget from the summed
                                  # step-time excess over the fleet mean
                                  # (repro.core.controller), clamped
                                  # [min_moves, max_moves_per_slot]
    min_moves: int = 1
    depth_decay: float = 0.5     # EWMA decay of the step-time ratios
    hysteresis: bool = False     # latch busy/idle between enter/exit
                                  # ratio levels + dwell
    exit_margin: float = 0.10    # busy exits below θ_b−margin × median,
                                  # idle exits above θ_i+margin × median
    dwell: int = 3               # slots a raw signal must persist


@dataclass
class DelegationBalancer:
    """Source-side CG balancer for pipeline shards across hosts."""
    n_hosts: int
    cfg: StragglerConfig = field(default_factory=StragglerConfig)

    def __post_init__(self):
        self._hist: list[deque] = [deque(maxlen=self.cfg.window)
                                   for _ in range(self.n_hosts)]
        self._dcfg = delegation.DelegationConfig(
            n_workers=self.n_hosts, n_virtual=0,
            max_moves_per_slot=self.cfg.max_moves_per_slot, fcfs=True)
        self._queues = delegation.init_queues(self.n_hosts)
        self.moves: list[tuple[int, int]] = []
        # adaptive controller over the step-time/median ratio: busy
        # enters above θ_b and exits below θ_b − margin (idle
        # symmetric); the budget follows the summed ratio excess
        if self.cfg.adaptive_moves or self.cfg.hysteresis:
            c = self.cfg
            self._controller = controller.DelegationController.from_thresholds(
                controller.ControllerConfig(
                    n_workers=self.n_hosts,
                    adaptive_moves=c.adaptive_moves,
                    min_moves=c.min_moves,
                    max_moves=c.max_moves_per_slot,
                    depth_decay=c.depth_decay,
                    hysteresis=c.hysteresis, dwell=c.dwell),
                theta_busy=c.theta_busy, theta_idle=c.theta_idle,
                margin=c.exit_margin)
        else:
            self._controller = None

    @property
    def flap_count(self) -> int:
        """Cumulative busy/idle signal flips (controller telemetry)."""
        return self._controller.flaps if self._controller else 0

    def observe(self, host: int, step_time_s: float) -> None:
        self._hist[host].append(step_time_s)

    def _means(self) -> list[float]:
        return [np.mean(h) if h else np.nan for h in self._hist]

    def signals(self) -> tuple[list[int], list[int]]:
        """Binary delegation signals after the current slot."""
        means = self._means()
        med = np.nanmedian(means)
        busy, idle = [], []
        if not np.isfinite(med) or med <= 0:
            return busy, idle
        for h, m in enumerate(means):
            if not np.isfinite(m):
                continue
            if m > self.cfg.theta_busy * med:
                busy.append(h)
            elif m < self.cfg.theta_idle * med:
                idle.append(h)
        return busy, idle

    def rebalance(self, pipeline) -> list[tuple[int, int]]:
        """Pair busy→idle hosts (severity order, FCFS carry-over across
        slots, bounded per slot) and move one shard per pair.
        ``pipeline`` must expose move_shard()."""
        means = np.asarray(self._means(), np.float32)
        pressure = np.where(np.isfinite(means), means, 0.0)
        budget = None
        if self._controller is not None:
            med = float(np.nanmedian(means))
            if not np.isfinite(med) or med <= 0:
                return []
            # a host with no samples sits at ratio 1.0: neither busy
            # nor idle, and it contributes no depth excess
            ratio = np.where(np.isfinite(means), means / med, 1.0)
            busy_j, idle_j, budget_j = self._controller.step(
                ratio.astype(np.float32), ratio.astype(np.float32), 1.0)
            busy_mask, idle_mask = np.asarray(busy_j), np.asarray(idle_j)
            budget = budget_j if self.cfg.adaptive_moves else None
        else:
            busy, idle = self.signals()
            busy_mask = np.zeros(self.n_hosts, bool)
            busy_mask[busy] = True
            idle_mask = np.zeros(self.n_hosts, bool)
            idle_mask[idle] = True
        src, dst, n_pairs, self._queues = delegation.plan_pairs(
            self._dcfg, self._queues, jnp.asarray(pressure),
            jnp.asarray(busy_mask), jnp.asarray(idle_mask), budget)
        src, dst = np.asarray(src), np.asarray(dst)
        moved = []
        for j in range(int(n_pairs)):
            sid = pipeline.move_shard(int(src[j]), int(dst[j]))
            if sid is not None:
                moved.append((int(src[j]), int(dst[j])))
        self.moves.extend(moved)
        return moved
