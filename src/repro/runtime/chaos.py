"""Fault injection — seeded, scripted failure schedules.

The paper's premise is that worker capacities must be *inferred* at run
time; the most violent capacity change is a worker dying (capacity→0)
or coming back (0→capacity, which must be re-admitted gradually or the
owner map flaps). This module scripts exactly those events so the
serving engine and the heterogeneous benchmarks can rehearse them
deterministically:

* ``ChaosEvent`` — one scripted fault: a replica **crash** (process
  stops serving and heartbeating; the monitor detects it by heartbeat
  expiry), a **slow**-down (service capacity divided by ``factor`` —
  the cpulimit'ed workers of Fig 15, injected mid-run), or a
  **recover** (process returns, subject to the engine's re-admission
  ramp).
* ``ChaosSchedule`` — an ordered event list consumed step by step via
  ``pop_due``. Anything exposing ``pop_due(step) -> list[ChaosEvent]``
  can be handed to ``ServingEngine(chaos=...)`` — the engine never
  imports this module, so schedules compose freely in tests.

Schedules are data, not randomness: ``ChaosSchedule.random`` *derives*
a script from a seed once, after which the run is exactly repeatable —
the same property the deterministically-seeded pipeline shards give
restart-after-failure replays.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

KINDS = ("crash", "slow", "recover")


@dataclass(frozen=True)
class ChaosEvent:
    step: int          # engine step the event fires at (1-based ticks)
    kind: str          # "crash" | "slow" | "recover"
    replica: int
    factor: float = 1.0   # slowdown divisor for "slow" (2.0 = half speed)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"use one of {KINDS}")


class ChaosSchedule:
    """Ordered fault script. ``pop_due`` hands out events whose step has
    arrived (each at most once); ``reset`` rewinds for a fresh run over
    the same scenario."""

    def __init__(self, events=()):
        self.events: list[ChaosEvent] = sorted(events, key=lambda e: e.step)
        self._i = 0

    def __len__(self) -> int:
        return len(self.events)

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self.events)

    def reset(self) -> None:
        self._i = 0

    def pop_due(self, step: int) -> list[ChaosEvent]:
        due = []
        while (self._i < len(self.events)
               and self.events[self._i].step <= step):
            due.append(self.events[self._i])
            self._i += 1
        return due

    # -- scenario constructors -------------------------------------------
    @classmethod
    def kill_one(cls, replica: int, at: int,
                 recover_at: int | None = None) -> "ChaosSchedule":
        """The canonical kill-1-of-N scenario: crash ``replica`` at step
        ``at``, optionally bring it back at ``recover_at``."""
        events = [ChaosEvent(at, "crash", replica)]
        if recover_at is not None:
            if recover_at <= at:
                raise ValueError("recover_at must come after the crash")
            events.append(ChaosEvent(recover_at, "recover", replica))
        return cls(events)

    @classmethod
    def slowdown(cls, replica: int, at: int, factor: float,
                 recover_at: int | None = None) -> "ChaosSchedule":
        """Divide ``replica``'s service capacity by ``factor`` from step
        ``at`` (a mid-run cpulimit), optionally restoring it later."""
        events = [ChaosEvent(at, "slow", replica, factor=factor)]
        if recover_at is not None:
            events.append(ChaosEvent(recover_at, "recover", replica))
        return cls(events)

    @classmethod
    def random(cls, seed: int, n_replicas: int, n_steps: int, *,
               p_crash: float = 0.002, mean_downtime: int = 20,
               p_slow: float = 0.0, slow_factor: float = 4.0,
               mean_slowtime: int = 20) -> "ChaosSchedule":
        """A seeded random script: at most one replica is down at a time
        (crash→delayed recovery loops), independent slowdown episodes on
        the others. Crash and slow episodes never overlap on one replica
        — ``apply_chaos`` treats "recover" kind-agnostically, so a slow
        episode's recover landing mid-downtime would revive the corpse
        early and break the one-down-at-a-time invariant. Derived once
        from ``seed`` — re-running the schedule replays the identical
        fault sequence."""
        rng = np.random.default_rng(seed)
        events: list[ChaosEvent] = []
        down_until, down_replica = 0, -1
        slow_until = np.zeros(n_replicas, np.int64)
        for step in range(1, n_steps + 1):
            if step >= down_until and rng.random() < p_crash:
                # never crash a replica mid-slow-episode: its pending
                # slow recover would cut the crash downtime short
                up = [r for r in range(n_replicas)
                      if slow_until[r] <= step]
                if up:
                    r = up[int(rng.integers(len(up)))]
                    dt = max(1, int(rng.exponential(mean_downtime)))
                    events.append(ChaosEvent(step, "crash", r))
                    events.append(ChaosEvent(min(step + dt, n_steps),
                                             "recover", r))
                    down_until, down_replica = step + dt, r
            if p_slow > 0:
                for r in range(n_replicas):
                    if r == down_replica and step < down_until:
                        continue   # no slow episodes on the down replica
                    if step >= slow_until[r] and rng.random() < p_slow:
                        dt = max(1, int(rng.exponential(mean_slowtime)))
                        events.append(ChaosEvent(step, "slow", r,
                                                 factor=slow_factor))
                        events.append(ChaosEvent(min(step + dt, n_steps),
                                                 "recover", r))
                        slow_until[r] = step + dt
        return cls(events)
