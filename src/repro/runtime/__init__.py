from .chaos import ChaosEvent, ChaosSchedule  # noqa: F401
from .fault_tolerance import (FaultTolerantRunner, FTConfig,  # noqa: F401
                              VWStateMigrator, plan_remesh)
from .straggler import DelegationBalancer, StragglerConfig  # noqa: F401
