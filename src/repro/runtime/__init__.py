from .fault_tolerance import FaultTolerantRunner, FTConfig, plan_remesh  # noqa: F401
from .straggler import DelegationBalancer, StragglerConfig  # noqa: F401
