"""Deterministic token data pipeline with CG-based heterogeneous sharding.

The paper's technique at site (b) (DESIGN.md §4): data-parallel hosts
are the *workers*, pipeline shards are the *virtual workers*. Shard →
host assignment follows the CG runtime: hosts that fall behind
(straggler signal from ``repro.runtime.straggler``) give shards up via
paired moves; routing changes affect only future batches (no message
migration). Shards are seeded deterministically, so restart-after-
failure replays the exact stream suffix from the checkpointed step.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streams


@dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    n_shards_per_host: int = 8     # virtual workers (α)
    zipf_z: float = 1.1            # token skew of the synthetic corpus
    seed: int = 0


class ShardedTokenPipeline:
    """Synthetic skewed-corpus pipeline (the substrate the paper's WP/TW
    traces stand in for). Every (shard, step) batch is a pure function of
    (seed, shard_id, step) — restartable and order-independent."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.n_shards = cfg.n_hosts * cfg.n_shards_per_host
        # shard → host assignment (the CG virtual-worker table)
        self.shard_owner = np.repeat(np.arange(cfg.n_hosts),
                                     cfg.n_shards_per_host)
        self._probs = jnp.asarray(
            streams.zipf_probs(cfg.vocab, cfg.zipf_z), jnp.float32)

    # -- CG pairing hook (runtime.straggler calls this) ------------------
    def move_shard(self, from_host: int, to_host: int) -> int | None:
        """Move one shard from an overloaded host to an idle one (paired
        move). Returns the shard id or None if from_host owns none."""
        owned = np.flatnonzero(self.shard_owner == from_host)
        if len(owned) == 0:
            return None
        sid = int(owned[-1])
        self.shard_owner[sid] = to_host
        return sid

    def shards_of(self, host: int) -> np.ndarray:
        return np.flatnonzero(self.shard_owner == host)

    # -- batch generation -------------------------------------------------
    def _shard_batch(self, shard_id: int, step: int, n_seq: int):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), shard_id),
            step)
        return jax.random.choice(
            key, self.cfg.vocab, shape=(n_seq, self.cfg.seq_len),
            p=self._probs).astype(jnp.int32)

    def host_batch(self, host: int, step: int) -> jnp.ndarray:
        """The host's share of the global batch at ``step``, produced by
        its currently-owned shards (CG: share follows capacity)."""
        shards = self.shards_of(host)
        per_shard = max(1, self.cfg.global_batch // self.n_shards)
        parts = [self._shard_batch(int(s), step, per_shard) for s in shards]
        if not parts:
            return jnp.zeros((0, self.cfg.seq_len), jnp.int32)
        return jnp.concatenate(parts, axis=0)

    def global_batch(self, step: int) -> jnp.ndarray:
        """All shards' batches in shard order (single-controller mode)."""
        per_shard = max(1, self.cfg.global_batch // self.n_shards)
        parts = [self._shard_batch(s, step, per_shard)
                 for s in range(self.n_shards)]
        out = jnp.concatenate(parts, axis=0)
        return out[: self.cfg.global_batch]
