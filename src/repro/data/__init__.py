from .pipeline import PipelineConfig, ShardedTokenPipeline  # noqa: F401
