from . import checkpointer  # noqa: F401
from .checkpointer import AsyncCheckpointer, latest_step, restore, save  # noqa: F401
