"""Sharded, atomic, async checkpointing.

Layout:  <dir>/step_<n>/
           manifest.json          — tree structure, shapes, dtypes, step
           shard_<i>.npz          — flattened leaves (host-local arrays)

Fault-tolerance contract (runtime.fault_tolerance):
  * writes go to ``step_<n>.tmp`` then os.rename → a crash mid-write can
    never corrupt the latest checkpoint;
  * ``latest_step`` scans only committed directories;
  * saves can run on a background thread (async_save) so the train loop
    overlaps device compute with host I/O — the paper's "no message
    migration" principle at step granularity: a restore affects only
    future steps, never in-flight ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for x in leaves:
        a = np.asarray(x)
        if a.dtype.name == "bfloat16":      # npz has no bf16 — widen
            a = a.astype(np.float32)
        out.append(a)
    return out, treedef


def save(ckpt_dir: str, step: int, tree, *, max_keep: int = 3) -> str:
    """Atomic synchronous save. Returns the committed directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "shard_0.npz"),
             **{f"leaf_{i}": x for i, x in enumerate(leaves)})
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(x.shape) for x in leaves],
        "dtypes": [str(x.dtype) for x in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, max_keep)
    return final


def _gc(ckpt_dir: str, max_keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-max_keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); shapes/dtypes are validated."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    like_leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(like_leaves), \
        f"leaf count mismatch: {len(leaves)} vs {len(like_leaves)}"
    out = []
    for got, want in zip(leaves, like_leaves):
        assert tuple(got.shape) == tuple(want.shape), \
            f"shape mismatch {got.shape} vs {want.shape}"
        out.append(np.asarray(got).astype(
            np.float32 if str(want.dtype) == "bfloat16" else want.dtype)
            if str(want.dtype) == "bfloat16"
            else got.astype(want.dtype))
    restored = jax.tree.unflatten(treedef, out)
    # re-narrow bf16 leaves on device
    return jax.tree.map(
        lambda r, w: jnp.asarray(r, w.dtype) if str(w.dtype) == "bfloat16"
        else r, restored, like)


class AsyncCheckpointer:
    """Background-thread saver: snapshot on the caller thread (device →
    host), write on the worker. At most one in-flight save; a new save
    waits for the previous one (bounded host memory)."""

    def __init__(self, ckpt_dir: str, max_keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.max_keep = max_keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device→host now

        def work():
            save(self.ckpt_dir, step, host_tree, max_keep=self.max_keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
