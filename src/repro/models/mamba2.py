"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) decoder LM.

The sequence mixer is the chunked SSD recurrence. Two interchangeable
implementations of identical math:
  * ``ssd_chunked`` — pure jnp (XLA), used inside the model so the
    512-device dry-run lowers through stock SPMD;
  * ``repro.kernels.ssd_scan`` — the Pallas TPU kernel (VMEM-resident
    state across chunks), selected on TPU.
Decode keeps O(1) state: [H, P, N] SSM state + conv ring — this is why
mamba2/zamba2 run the ``long_500k`` cell (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from .layers import dense_init, rmsnorm, shard_act
from .lm_common import (chunked_xent, embed_tokens, last_logits, norm,
                        norm_params, pick_chunk, shift_labels)


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    d_xbc = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, H, d_xbc


def _layer_init(key, cfg, dtype):
    s, d_in, H, d_xbc = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "norm": norm_params(cfg, dtype),
        "in_proj": dense_init(ks[0], (d, d_in + d_xbc + H), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, d_xbc), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus ≈ 0.12
        "ssm_norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_l = jax.random.split(key)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jax.random.split(k_l, cfg.n_layers))
    return {
        "embed": dense_init(k_e, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "layers": layers,
        "final_norm": norm_params(cfg, dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD (jnp) — identical math to kernels/ssd_scan
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, return_state: bool = False):
    """x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,G,N] → y [B,L,H,P]
    (+ final state [B,H,P,N] when return_state).

    B/C stay in *group* form [.., G, N] through the scan and expand to
    heads only inside each step — passing head-expanded stacks through
    the scan multiplied the sliced bytes (and their SPMD gathers) by
    H/G (§Perf H3 iteration 1).
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = L // chunk

    def rs(a):
        return jnp.moveaxis(a.reshape(Bsz, nc, chunk, *a.shape[2:]), 1, 0)

    xs = (rs(x.astype(jnp.float32)), rs(dt.astype(jnp.float32)),
          rs(Bm.astype(jnp.float32)), rs(Cm.astype(jnp.float32)))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(h, inp):
        xc, dtc, bc, cc = inp                        # [B,Q,H,P],[B,Q,H],[B,Q,G,N]
        bch = jnp.repeat(bc, rep, axis=2)            # local head expand
        cch = jnp.repeat(cc, rep, axis=2)
        da = dtc * A[None, None, :]                  # [B,Q,H]
        s = jnp.cumsum(da, axis=1)
        g = jnp.einsum("bqhn,bkhn->bhqk", cch, bch)
        diff = s[:, :, None, :] - s[:, None, :, :]   # [B,Q,K,H]
        diff = jnp.moveaxis(diff, -1, 1)             # [B,H,Q,K]
        w = jnp.where(mask[None, None], jnp.exp(jnp.where(mask[None, None], diff, 0.0)), 0.0)
        w = w * g * jnp.moveaxis(dtc, -1, 1)[:, :, None, :]
        y = jnp.einsum("bhqk,bkhp->bqhp", w, xc)
        # inter-chunk
        sm = jnp.moveaxis(s, -1, 1)                  # [B,H,Q]
        y = y + jnp.moveaxis(
            jnp.exp(sm)[..., None] * jnp.einsum("bqhn,bhpn->bhqp", cch, h),
            1, 2)
        coef = dtc * jnp.exp(s[:, -1:, :] - s)       # [B,Q,H]
        h_new = jnp.exp(sm[:, :, -1])[..., None, None] * h + jnp.einsum(
            "bqhp,bqhn->bhpn", xc * coef[..., None], bch)
        # the scan carry is saved per chunk for the backward — shard it
        # over heads or its stack dominates peak memory (zamba2: 80 heads
        # × [P,N] f32 per chunk)
        h_new = shard_act(h_new, "bhpn")
        return h_new, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_fin, y = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, L, H, P).astype(x.dtype)
    if return_state:
        return y, h_fin
    return y


def _ssd(x, dt, A, Bm, Cm, cfg):
    if cfg.use_pallas == "always" or (
            cfg.use_pallas == "auto" and jax.default_backend() == "tpu"):
        return kops.ssd_scan(x, dt, A, Bm, Cm, chunk=cfg.ssm.chunk)
    return ssd_chunked(x, dt, A, Bm, Cm, pick_chunk(x.shape[1], cfg.ssm.chunk))


# ---------------------------------------------------------------------------
# Block forward (train/prefill)
# ---------------------------------------------------------------------------

def mamba_block(x, lp, cfg, return_state: bool = False):
    """x: [B, S, D] → [B, S, D] (residual NOT included).

    return_state: also return (conv_tail [B, d_conv-1, d_xbc], h_final
    [B, H, P, N]) for decode continuation after prefill.
    """
    s, d_in, H, d_xbc = _dims(cfg)
    B, S, D = x.shape
    zxbcdt = x @ lp["in_proj"]
    z, xbc_raw, dt = jnp.split(zxbcdt, [d_in, d_in + d_xbc], axis=-1)
    # causal depthwise conv over xbc, window d_conv
    pads = jnp.zeros((B, s.d_conv - 1, d_xbc), xbc_raw.dtype)
    xp = jnp.concatenate([pads, xbc_raw], axis=1)
    xbc = sum(xp[:, i:i + S] * lp["conv_w"][i][None, None]
              for i in range(s.d_conv)) + lp["conv_b"]
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    xh = xs.reshape(B, S, H, s.head_dim)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    if return_state:
        y, h_fin = ssd_chunked(xh, dtv, A, Bm, Cm,
                               pick_chunk(S, cfg.ssm.chunk),
                               return_state=True)
    else:
        y = _ssd(xh, dtv, A, Bm, Cm, cfg)
    y = y + lp["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), lp["ssm_norm"])
    out = y @ lp["out_proj"]
    if return_state:
        return out, (xbc_raw[:, S - (s.d_conv - 1):], h_fin)
    return out


def hidden_states(params, cfg, x):
    def body(x, lp):
        x = x + mamba_block(norm(x, lp["norm"], cfg), lp, cfg)
        return shard_act(x, "btd"), None

    from .transformer import _remat
    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return norm(x, params["final_norm"], cfg)


def loss_fn(params, cfg, batch):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    x = hidden_states(params, cfg, x)
    return chunked_xent(x, params["embed"], shift_labels(tokens))


def prefill_step(params, cfg, batch, pad_to: int | None = None):  # noqa: ARG001 (stateless cache)
    """Prefill: forward over the prompt, returning last logits + the O(1)
    recurrent state (conv tails + SSM states) as the decode cache."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")

    def body(x, lp):
        y, (conv, h) = mamba_block(norm(x, lp["norm"], cfg), lp, cfg,
                                   return_state=True)
        return shard_act(x + y, "btd"), (conv, h)

    from .transformer import _remat
    body = _remat(body, cfg)
    x, (conv, h) = jax.lax.scan(body, x, params["layers"])
    x = norm(x, params["final_norm"], cfg)
    logits = last_logits(x[:, -1], params["embed"])
    S = tokens.shape[1]
    return logits, {"conv": conv.astype(jnp.dtype(cfg.dtype)), "h": h,
                    "pos": jnp.asarray(S, jnp.int32)}


# ---------------------------------------------------------------------------
# Decode (O(1) state)
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch: int, max_len: int):
    """max_len only sets ``pos`` semantics — state is O(1) in seq len."""
    s, d_in, H, d_xbc = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, s.d_conv - 1, d_xbc), dtype),
        "h": jax.ShapeDtypeStruct((L, batch, H, s.head_dim, s.d_state),
                                  jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                        cache_spec(cfg, batch, max_len))


def mamba_step(xt, lp, cfg, conv_state, h):
    """Single-token recurrence. xt: [B, D] → ([B, D], conv_state, h)."""
    s, d_in, H, d_xbc = _dims(cfg)
    B = xt.shape[0]
    zxbcdt = xt @ lp["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + d_xbc], axis=-1)
    win = jnp.concatenate([conv_state, xbc[:, None]], axis=1)   # [B, dc, C]
    xbc = jnp.einsum("bdc,dc->bc", win.astype(jnp.float32),
                     lp["conv_w"].astype(jnp.float32)) + lp["conv_b"]
    xbc = jax.nn.silu(xbc).astype(xt.dtype)
    conv_state = win[:, 1:]
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    xh = xs.reshape(B, H, s.head_dim).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(B, s.n_groups, s.d_state),
                    H // s.n_groups, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(B, s.n_groups, s.d_state),
                    H // s.n_groups, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])   # [B, H]
    A = -jnp.exp(lp["A_log"])
    decay = jnp.exp(dtv * A[None])[..., None, None]                 # [B,H,1,1]
    h = decay * h + (dtv[..., None] * xh)[..., None] * Bm[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm)
    y = y + lp["D"][None, :, None] * xh
    y = y.reshape(B, d_in).astype(xt.dtype)
    y = rmsnorm(y * jax.nn.silu(z), lp["ssm_norm"])
    return y @ lp["out_proj"], conv_state, h


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)[:, 0]   # [B, D]

    def body(x, xs):
        lp, conv, h = xs
        y, conv, h = mamba_step(norm(x, lp["norm"], cfg), lp, cfg, conv, h)
        return x + y, (conv, h)

    x, (conv_new, h_new) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["h"]))
    x = norm(x, params["final_norm"], cfg)
    return last_logits(x, params["embed"]), {
        "conv": conv_new, "h": h_new, "pos": cache["pos"] + 1}
