"""Sequence-parallel decode attention (flash-decoding, TPU-native).

Baseline GSPMD lowering of decode attention over a seq-sharded KV cache
all-gathers the cache every step — the §Roofline tables show every
decode cell collective-dominant because of it. This module is the §Perf
fix: an explicit shard_map over the "model" axis where each shard

  1. writes the new K/V into its slice iff the write position falls in
     its range (no cross-shard DUS resharding), and
  2. computes attention over its local cache slice, combining the
     per-shard (max, Σexp, Σexp·v) with a log-sum-exp psum — bytes moved
     per step: O(B·H·Dh) instead of O(B·S·KV·Dh).

Falls back to the dense path when no mesh is installed or the cache's
seq axis isn't sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _ACT_RULES, _expand_kv, decode_attention
from .lm_common import update_kv_cache

_NEG = jnp.float32(-1e30)


def _mesh_and_dp():
    mesh = _ACT_RULES.get("_mesh")
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None, None
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return mesh, dp


def _dp_ok(mesh, dp, b):
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return b % n == 0 and b >= n


def seqpar_update_and_attend(q, k_cache, v_cache, k_new, v_new, pos,
                             lo=None):
    """Fused cache write + decode attention, seq-parallel over "model".

    q: [B, 1, H, Dh]; caches: [B, S, KV, Dh]; k_new/v_new: [B, 1, KV, Dh];
    pos: [] int32; lo: optional [] int32 window lower bound (entries
    below it masked — sliding-window decode).
    Returns (out [B, 1, H, Dh], k_cache, v_cache).
    """
    mesh, dp = _mesh_and_dp()
    B, S = k_cache.shape[0], k_cache.shape[1]
    n_model = mesh.shape["model"] if mesh is not None else 1
    if (mesh is None or n_model == 1 or S % n_model != 0
            or S < n_model * 2):
        kc, vc = update_kv_cache(k_cache, v_cache, k_new, v_new, pos)
        return decode_attention(q, kc, vc, pos + 1, lo_idx=lo), kc, vc

    bspec = dp if _dp_ok(mesh, dp, B) else None
    cache_spec = P(bspec, "model", None, None)
    new_spec = P(bspec, None, None, None)
    q_spec = P(bspec, None, None, None)
    if lo is None:
        lo = jnp.zeros((), jnp.int32)

    def local_fn(q, kc, vc, kn, vn, pos, lo):
        ax = jax.lax.axis_index("model")
        S_loc = kc.shape[1]
        start = ax * S_loc
        li = jnp.clip(pos - start, 0, S_loc - 1)
        in_rng = (pos >= start) & (pos < start + S_loc)
        old_k = jax.lax.dynamic_slice(kc, (0, li, 0, 0), kn.shape)
        old_v = jax.lax.dynamic_slice(vc, (0, li, 0, 0), vn.shape)
        kc = jax.lax.dynamic_update_slice(
            kc, jnp.where(in_rng, kn.astype(kc.dtype), old_k), (0, li, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, jnp.where(in_rng, vn.astype(vc.dtype), old_v), (0, li, 0, 0))

        H = q.shape[2]
        k = _expand_kv(kc, H)
        v = _expand_kv(vc, H)
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        idx = start + jnp.arange(S_loc)
        valid = (idx < pos + 1) & (idx >= lo)
        s = jnp.where(valid[None, None, None, :], s, _NEG)
        m = jnp.max(s, axis=-1)                          # [B,H,1]
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
        # LSE-combine across seq shards — O(B·H·Dh) on the wire
        M = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - M)
        L = jax.lax.psum(l * corr, "model")
        O = jax.lax.psum(o * corr[..., None], "model")
        out = (O / jnp.maximum(L, 1e-30)[..., None])
        return jnp.moveaxis(out, 1, 2).astype(q.dtype), kc, vc

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, new_spec, new_spec,
                  P(), P()),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_vma=False)
    return fn(q, k_cache, v_cache, k_new, v_new, pos, lo)


def seqpar_attend(q, k_cache, v_cache, valid_len):
    """Read-only seq-parallel decode attention (e.g. cross-attention
    against a static encoder memory). Same LSE combine, no cache write."""
    mesh, dp = _mesh_and_dp()
    B, S = k_cache.shape[0], k_cache.shape[1]
    n_model = mesh.shape["model"] if mesh is not None else 1
    if (mesh is None or n_model == 1 or S % n_model != 0
            or S < n_model * 2):
        return decode_attention(q, k_cache, v_cache, valid_len)

    bspec = dp if _dp_ok(mesh, dp, B) else None
    cache_spec = P(bspec, "model", None, None)
    q_spec = P(bspec, None, None, None)

    def local_fn(q, kc, vc, valid_len):
        ax = jax.lax.axis_index("model")
        S_loc = kc.shape[1]
        start = ax * S_loc
        H = q.shape[2]
        k = _expand_kv(kc, H)
        v = _expand_kv(vc, H)
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        valid = (start + jnp.arange(S_loc)) < valid_len
        s = jnp.where(valid[None, None, None, :], s, _NEG)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
        M = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - M)
        L = jax.lax.psum(l * corr, "model")
        O = jax.lax.psum(o * corr[..., None], "model")
        out = O / jnp.maximum(L, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)

    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(q_spec, cache_spec, cache_spec, P()),
                       out_specs=q_spec, check_vma=False)
    return fn(q, k_cache, v_cache, valid_len)
