"""Shared LM machinery: embeddings, chunked loss, cache plumbing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import layernorm, rmsnorm, shard_act


def norm(x, p, cfg):
    if cfg.norm_kind == "ln":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_params(cfg, dtype):
    if cfg.norm_kind == "ln":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def embed_tokens(embed, tokens, d_model):
    embed = shard_act(embed, "vd")   # also pins d_embed's sharding in bwd
    x = jnp.take(embed, tokens, axis=0)
    return x * jnp.asarray(jnp.sqrt(d_model), x.dtype)


def chunked_xent(x: jnp.ndarray, embed: jnp.ndarray, labels: jnp.ndarray,
                 chunk: int = 512) -> jnp.ndarray:
    """Mean next-token cross-entropy without materializing [B,S,V].

    x: [B, S, D] final hidden states; embed: [V, D] (tied head);
    labels: [B, S] int32 (already shifted; -1 = ignore).
    Scans over sequence chunks so the live logits tensor is [B,chunk,V].
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    xs = x[:, : n * chunk].reshape(B, n, chunk, D)
    ls = labels[:, : n * chunk].reshape(B, n, chunk)

    embed = shard_act(embed, "vd")   # pins d_embed accumulation sharding

    @jax.checkpoint      # recompute the [B,C,V] logits in backward
    def step(carry, xi):
        tot, cnt = carry
        xc, lc = xi                                  # [B, C, D], [B, C]
        logits = jnp.einsum("bcd,vd->bcv", xc.astype(jnp.float32),
                            embed.astype(jnp.float32))
        logits = shard_act(logits, "bcv")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - tgt) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0), jnp.float32(0)),
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)


def last_logits(x_last: jnp.ndarray, embed: jnp.ndarray) -> jnp.ndarray:
    """Decode-step logits: x_last [B, D] → [B, V] (f32).

    The vd constraint keeps the (model, data)-sharded table in place —
    the d-contraction resolves as a psum of [B, V/shards] partials
    instead of an all-gather of the table (§Perf H2 iteration 2).
    """
    embed = shard_act(embed, "vd")
    logits = jnp.einsum("bd,vd->bv", x_last.astype(jnp.float32),
                        embed.astype(jnp.float32))
    return shard_act(logits, "bv")


def shift_labels(tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token labels: labels[t] = tokens[t+1], last = ignore."""
    return jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)


def pad_cache_seq(kv: jnp.ndarray, pad_to: int | None, axis: int = 2):
    """Zero-pad a stacked KV cache [..., S, KV, Dh] along seq to pad_to
    (headroom for decode continuation)."""
    if pad_to is None or kv.shape[axis] >= pad_to:
        return kv
    pads = [(0, 0)] * kv.ndim
    pads[axis] = (0, pad_to - kv.shape[axis])
    return jnp.pad(kv, pads)


def pick_chunk(seq: int, target: int) -> int:
    """Largest divisor of ``seq`` that is ≤ target (SSD chunk picking)."""
    c = min(target, seq)
    while seq % c != 0:
        c -= 1
    return c


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos):
    """Write [B, 1, KV, Dh] at position ``pos`` of [B, S, KV, Dh]."""
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache
