"""Family dispatcher: one uniform API over all assigned architectures.

API:
  init_params(cfg, key)             → param pytree
  loss_fn(params, cfg, batch)       → scalar loss       (train/prefill)
  decode_step(params, cfg, cache, tokens) → (logits, cache)
  cache_spec(cfg, batch, max_len)   → ShapeDtypeStruct pytree
  input_specs(cfg, shape)           → dry-run input ShapeDtypeStructs
  count_params(tree) / active_params(cfg, tree)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

from . import encdec, hybrid, mamba2, moe_transformer, transformer

_FAMS = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe_transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "audio": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMS[cfg.family]


def init_params(cfg: ModelConfig, key):
    return family_module(cfg).init_params(cfg, key)


def loss_fn(params, cfg: ModelConfig, batch):
    return family_module(cfg).loss_fn(params, cfg, batch)


def loss_and_metrics(params, cfg: ModelConfig, batch):
    """(loss, aux metrics dict). MoE models surface the CG-routing
    telemetry (moe_drop_frac, moe_max_load_frac, moe_load [E]); other
    families return an empty dict."""
    if cfg.family == "moe":
        return moe_transformer.loss_fn(params, cfg, batch,
                                       with_metrics=True)
    return family_module(cfg).loss_fn(params, cfg, batch), {}


def metric_zeros(cfg: ModelConfig) -> dict:
    """Zero-valued pytree matching loss_and_metrics' aux dict (the
    grad-accum scan carry / out-sharding template)."""
    if cfg.family != "moe":
        return {}
    return {"moe_drop_frac": jnp.float32(0),
            "moe_max_load_frac": jnp.float32(0),
            "moe_load": jnp.zeros((cfg.moe.n_experts,), jnp.float32)}


def _use_longctx(cfg: ModelConfig, max_len: int) -> bool:
    return (cfg.family == "dense" and cfg.sliding_window is not None
            and cfg.global_every is not None and max_len > 65536)


def decode_step(params, cfg: ModelConfig, cache, tokens):
    mod = family_module(cfg)
    if "local_k" in cache:
        return transformer.decode_step_longctx(params, cfg, cache, tokens)
    return mod.decode_step(params, cfg, cache, tokens)


def prefill_step(params, cfg: ModelConfig, batch, pad_to: int | None = None):
    """Inference prefill → (last logits, primed decode cache)."""
    return family_module(cfg).prefill_step(params, cfg, batch, pad_to=pad_to)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    if _use_longctx(cfg, max_len):
        return transformer.longctx_cache_spec(cfg, batch, max_len)
    return family_module(cfg).cache_spec(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                        cache_spec(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train/prefill → {"batch": {...}}; decode → {"cache": ..., "tokens": ...}.
    No device allocation — safe under the 512-device dry-run.
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.dtype)),
                "tokens": jax.ShapeDtypeStruct((B, S), tok),
            }
        elif cfg.family == "vlm":
            batch = {
                "patches": jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)),
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.n_patches), tok),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        return {"batch": batch}
    # decode: one new token against a seq_len history
    return {
        "cache": cache_spec(cfg, B, S),
        "tokens": jax.ShapeDtypeStruct((B, 1), tok),
    }


def count_params(tree) -> int:
    return int(sum(x.size for x in jax.tree.leaves(tree)))


def count_params_specs(tree) -> int:
    return count_params(tree)


def active_params(cfg: ModelConfig, total: int) -> int:
    """Active params per token (MoE: top_k + shared of n_experts)."""
    if cfg.family != "moe":
        return total
    moe = cfg.moe
    expert_p = cfg.n_layers * moe.n_experts * 3 * cfg.d_model * moe.d_ff_expert
    active_e = cfg.n_layers * (moe.top_k + moe.n_shared_experts) \
        * 3 * cfg.d_model * moe.d_ff_expert
    return total - expert_p + active_e


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params without allocating (eval_shape)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
