"""Shared neural building blocks (pure-function style, param pytrees).

Everything here is mesh-agnostic; the launcher installs activation
sharding rules through ``set_act_sharding`` and the layers call
``shard_act`` at the canonical cut points. With no rules installed the
calls are identity (single-device smoke tests).
"""
from __future__ import annotations

import contextlib
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activation-sharding context (installed by repro.launch)
# ---------------------------------------------------------------------------

_ACT_RULES: dict[str, object] = {}


def set_act_sharding(rules: dict[str, object]) -> None:
    """Install {kind: PartitionSpec} activation constraints (launcher)."""
    global _ACT_RULES
    _ACT_RULES = dict(rules)


@contextlib.contextmanager
def act_sharding(rules: dict[str, object]):
    global _ACT_RULES
    old = _ACT_RULES
    _ACT_RULES = dict(rules)
    try:
        yield
    finally:
        _ACT_RULES = old


def shard_act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    spec = _ACT_RULES.get(kind)
    if spec is None:
        return x
    mesh = _ACT_RULES.get("_mesh")
    if mesh is None:
        return x
    # drop axes that do not divide the actual dim (e.g. batch=1 cells)
    def ax_size(axes):
        n = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            n *= mesh.shape[a]
        return n
    from jax.sharding import NamedSharding, PartitionSpec as P
    fixed = [axes if (axes is not None and d % ax_size(axes) == 0
                      and d >= ax_size(axes)) else None
             for d, axes in zip(x.shape, tuple(spec) + (None,) * x.ndim)]
    # NamedSharding carries its mesh — no ambient mesh context required
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Norms — custom VJPs that keep residuals in the model dtype.
#
# Without these, XLA stores the layer-scan's saved residual stream in
# f32 (the norm backward's first use of x is an f32 convert, so the
# convert gets folded into the save) — 2× activation memory at 100B
# scale. The custom bwd takes bf16 residuals and upcasts per-slice.
# ---------------------------------------------------------------------------

_RMS_EPS = 1e-6
_LN_EPS = 1e-5


@jax.custom_vjp
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + _RMS_EPS) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def _rms_fwd(x, scale):
    return rmsnorm(x, scale), (x, scale)


def _rms_bwd(res, g):
    x, scale = res
    # barrier: keeps XLA from commuting this convert past the bwd loop's
    # slice and materializing an f32 copy of the whole saved-carry stack
    x = jax.lax.optimization_barrier(x)
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + _RMS_EPS)
    s = 1.0 + scale.astype(jnp.float32)
    gs = gf * s
    dx = r * gs - xf * (r ** 3 / d) * jnp.sum(gs * xf, -1, keepdims=True)
    dscale = jnp.sum(gf * xf * r,
                     axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


@jax.custom_vjp
def layernorm(x: jnp.ndarray, scale: jnp.ndarray,
              bias: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + _LN_EPS)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def _ln_fwd(x, scale, bias):
    return layernorm(x, scale, bias), (x, scale)


def _ln_bwd(res, g):
    x, scale = res
    x = jax.lax.optimization_barrier(x)
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    d = x.shape[-1]
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + _LN_EPS)
    xhat = (xf - mu) * r
    gs = gf * scale.astype(jnp.float32)
    dx = r * (gs - jnp.mean(gs, -1, keepdims=True)
              - xhat * jnp.mean(gs * xhat, -1, keepdims=True))
    axes = tuple(range(x.ndim - 1))
    return (dx.astype(x.dtype), jnp.sum(gf * xhat, axes).astype(scale.dtype),
            jnp.sum(gf, axes).astype(scale.dtype))


layernorm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: [..., S] int32."""
    d_head = x.shape[-1]
    inv = rope_freqs(d_head, theta)                    # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * inv   # [..., S, Dh/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, Dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_NEG = jnp.float32(-1e30)


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, S, KV, Dh] -> [B, S, H, Dh] by group repeat."""
    rep = n_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def dense_attention(q, k, v, *, causal: bool, window: int | None = None,
                    q_offset: int = 0, window_flag=None) -> jnp.ndarray:
    """Materialized-scores attention for short sequences.

    q: [B, Sq, H, Dh]; k, v: [B, Sk, KV, Dh]. Returns [B, Sq, H, Dh].
    ``window_flag``: optional traced bool — when False the window mask is
    disabled at runtime (gemma3 local/global interleave inside scan).
    """
    H = q.shape[2]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    iq = jnp.arange(q.shape[1])[:, None] + q_offset
    jk = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask = mask & (iq >= jk)
    if window is not None:
        wmask = jk > iq - window
        if window_flag is not None:
            wmask = wmask | ~window_flag
        mask = mask & wmask
    s = jnp.where(mask[None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


@partial(jax.checkpoint, static_argnums=(4, 5, 6, 7, 8))
def _chunked_attn_body(q, k, v, window_flag, causal, window, q_chunk,
                       kv_chunk, q_offset):
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    qs = q.reshape(B, nq, q_chunk, H, Dh)
    ks = k.reshape(B, nk, kv_chunk, H, Dh)
    vs = v.reshape(B, nk, kv_chunk, H, Dh)

    def q_step(_, qi):
        qc, iq_blk = qi                                   # [B, qc, H, Dh]
        qpos = q_offset + iq_blk * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint   # flash-style bwd: recompute scores per kv chunk
        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, jk_blk = ki
            kpos = jk_blk * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window is not None:
                wmask = kpos[None, :] > qpos[:, None] - window
                if window_flag is not None:
                    wmask = wmask | ~window_flag
                mask = mask & wmask
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), _NEG)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # [B, H, qc, Dh]
        return None, jnp.moveaxis(out, 1, 2)              # [B, qc, H, Dh]

    _, out = jax.lax.scan(q_step, None,
                          (jnp.moveaxis(qs, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: int | None = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      q_offset: int = 0, window_flag=None) -> jnp.ndarray:
    """Online-softmax attention scanned over q and kv chunks.

    Keeps the S² score matrix out of live memory (flash-attention
    schedule, TPU-adapted as jnp-on-MXU). Causal self-attention takes
    the *triangular* schedule: q-chunk i attends only its first i+1 kv
    chunks (statically bounded per chunk → reverse-differentiable),
    halving attention FLOPs vs the rectangular sweep (§Perf H1).
    """
    if causal and q_offset == 0 and q.shape[1] == k.shape[1]:
        return _chunked_attn_tri(q, k, v, window_flag, window, q_chunk,
                                 kv_chunk)
    return _chunked_attn_body(q, k, v, window_flag, causal, window, q_chunk,
                              kv_chunk, q_offset)


@partial(jax.checkpoint, static_argnums=(4, 5, 6))
def _chunked_attn_tri(q, k, v, window_flag, window, q_chunk, kv_chunk):
    """Triangular causal schedule: per q chunk, scan exactly the causal
    kv-chunk prefix. Static bounds per (python-unrolled) q chunk.

    When ``window`` is static for every layer (window_flag is None), kv
    chunks entirely below the window are skipped statically too.
    """
    B, Sq, H, Dh = q.shape
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sq)
    nq, nk = Sq // q_chunk, Sq // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    ks = k.reshape(B, nk, kv_chunk, H, Dh)
    vs = v.reshape(B, nk, kv_chunk, H, Dh)
    outs = []
    for i in range(nq):
        qc = q[:, i * q_chunk:(i + 1) * q_chunk]
        qpos = i * q_chunk + jnp.arange(q_chunk)
        hi = (i + 1) * q_chunk
        hi_blk = -(-hi // kv_chunk)                  # ceil
        lo_blk = 0
        if window is not None and window_flag is None:
            lo_blk = max(0, (hi - q_chunk - window) // kv_chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kc, vc, jb = kj
            kpos = jb * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = qpos[:, None] >= kpos[None, :]
            if window is not None:
                wmask = kpos[None, :] > qpos[:, None] - window
                if window_flag is not None:
                    wmask = wmask | ~window_flag
                mask = mask & wmask
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), _NEG)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dh), jnp.float32)
        sel = jnp.arange(lo_blk, hi_blk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(ks[:, lo_blk:hi_blk], 1, 0),
             jnp.moveaxis(vs[:, lo_blk:hi_blk], 1, 0), sel))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.moveaxis(out, 1, 2))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid_len, lo_idx=None) -> jnp.ndarray:
    """Single-position attention against a (padded) KV cache.

    q: [B, 1, H, Dh]; caches: [B, S, KV, Dh]; valid_len: [] current length
    (entries at position ≥ valid_len are masked). ``lo_idx``: optional []
    lower bound — entries below it are masked (sliding window decode).
    """
    H = q.shape[2]
    k = _expand_kv(k_cache, H)
    v = _expand_kv(v_cache, H)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    idx = jnp.arange(k.shape[1])[None, None, None, :]
    mask = idx < valid_len
    if lo_idx is not None:
        mask = mask & (idx >= lo_idx)
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Projections / MLP
# ---------------------------------------------------------------------------

def linear(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def swiglu_mlp(x, p):
    """LLaMA-style gated MLP: w1 (gate), w3 (up), w2 (down)."""
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = shard_act(h, "btf")
    return h @ p["w2"]


def gelu_mlp(x, p):
    """2-matrix GELU MLP (whisper)."""
    h = jax.nn.gelu(x @ p["w1"] + p.get("b1", 0.0))
    return h @ p["w2"] + p.get("b2", 0.0)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_params(key, cfg, dtype):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, KV * Dh), dtype),
        "wv": dense_init(ks[2], (d, KV * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((KV * Dh,), dtype)
        p["bv"] = jnp.zeros((KV * Dh,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp_params(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff), dtype),
        "w3": dense_init(ks[1], (d_model, d_ff), dtype),
        "w2": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def attention(x, p, cfg, *, positions, causal=True, window=None,
              kv_override=None, window_flag=None, return_kv=False):
    """Full attention sub-layer: proj → rope → attend → out-proj.

    kv_override: (k, v) precomputed (cross-attention; no RoPE applied).
    window_flag: traced bool enabling the sliding window per layer.
    return_kv: also return the (roped) k/v for KV-cache priming.
    Returns output [B, S, D] (or (out, (k, v))).
    """
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, Dh)
    if kv_override is None:
        k = linear(x, p["wk"], p.get("bk")).reshape(B, S, KV, Dh)
        v = linear(x, p["wv"], p.get("bv")).reshape(B, S, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    q = shard_act(q, "bshd")
    k = shard_act(k, "bskd")
    v = shard_act(v, "bskd")
    if max(S, k.shape[1]) > cfg.attn_chunk_threshold:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                q_chunk=min(cfg.q_chunk, S),
                                kv_chunk=min(cfg.kv_chunk, k.shape[1]),
                                window_flag=window_flag)
    else:
        out = dense_attention(q, k, v, causal=causal, window=window,
                              window_flag=window_flag)
    out = out.reshape(B, S, H * Dh)
    from jax.ad_checkpoint import checkpoint_name
    out = checkpoint_name(out, "attn_out")
    out = linear(out, p["wo"], p.get("bo"))
    if return_kv:
        return out, (k, v)
    return out
