"""Dense decoder-only transformer (gemma3 / internlm2 / starcoder2 /
command-r-plus) + VLM variant (internvl2: stub patch frontend).

Layer stack is a ``lax.scan`` over stacked per-layer params (one compiled
layer body — compile-time hygiene for the 512-device dry-run), with a
configurable remat policy. gemma3's 5:1 local:global interleave is a
per-layer traced window flag.

For ``long_500k`` decode, gemma3 uses the **ring-buffer** path
(``init_longctx_cache``/``decode_step_longctx``): local layers hold a
window-sized rotating KV cache (sub-quadratic memory), only the 1-in-6
global layers keep the full history.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention, attn_params, decode_attention,
                     dense_init, gelu_mlp, linear, mlp_params, shard_act,
                     swiglu_mlp)
from .lm_common import (chunked_xent, embed_tokens, last_logits, norm,
                        norm_params, pad_cache_seq, shift_labels,
                        update_kv_cache)


def _mlp_params(key, cfg, dtype):
    if cfg.mlp_kind == "gelu":
        ks = jax.random.split(key, 2)
        p = {"w1": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
             "w2": dense_init(ks[1], (cfg.d_ff, cfg.d_model), dtype)}
        if cfg.use_bias:
            p["b1"] = jnp.zeros((cfg.d_ff,), dtype)
            p["b2"] = jnp.zeros((cfg.d_model,), dtype)
        return p
    return mlp_params(key, cfg.d_model, cfg.d_ff, dtype)


def _mlp(x, p, cfg):
    return gelu_mlp(x, p) if cfg.mlp_kind == "gelu" else swiglu_mlp(x, p)


def _layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": norm_params(cfg, dtype),
        "attn": attn_params(ks[0], cfg, dtype),
        "mlp_norm": norm_params(cfg, dtype),
        "mlp": _mlp_params(ks[1], cfg, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_l, k_v = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jax.random.split(k_l, cfg.n_layers))
    params = {
        "embed": dense_init(k_e, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "layers": layers,
        "final_norm": norm_params(cfg, dtype),
    }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(
            k_v, (cfg.vision_dim, cfg.d_model), dtype)
    return params


def _window_flags(cfg):
    """[L] bool — True where the sliding window applies (local layers)."""
    if cfg.sliding_window is None:
        return None
    L = cfg.n_layers
    if cfg.global_every is None:
        return jnp.ones((L,), bool)
    return (jnp.arange(L) % cfg.global_every) != (cfg.global_every - 1)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "attn_out":
        # save only the (cheap-to-store, expensive-to-recompute)
        # attention outputs; recompute everything else in backward
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def hidden_states(params, cfg, x, positions, collect_kv: bool = False):
    """Run the layer stack. x: [B, S, D] → [B, S, D] (final-normed).

    collect_kv=True also returns the stacked per-layer (k, v)
    [L, B, S, KV, Dh] for KV-cache priming (prefill).
    """
    flags = _window_flags(cfg)

    def body(x, xs):
        lp, flag = xs
        h, kv = attention(norm(x, lp["attn_norm"], cfg), lp["attn"], cfg,
                          positions=positions, causal=True,
                          window=cfg.sliding_window, window_flag=flag,
                          return_kv=True)
        x = x + h
        h = _mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"], cfg)
        x = x + h
        return shard_act(x, "btd"), (kv if collect_kv else None)

    body = _remat(body, cfg)
    if flags is None:
        flags = jnp.ones((cfg.n_layers,), bool)   # inert
    x, kvs = jax.lax.scan(body, x, (params["layers"], flags))
    x = norm(x, params["final_norm"], cfg)
    if collect_kv:
        return x, kvs
    return x


def loss_fn(params, cfg, batch):
    """Next-token CE. batch: {"tokens": [B, S]} (+"patches" for vlm)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    n_prefix = 0
    if cfg.family == "vlm":
        vis = batch["patches"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        n_prefix = vis.shape[1]
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    x = hidden_states(params, cfg, x, positions)
    labels = shift_labels(tokens)
    return chunked_xent(x[:, n_prefix:], params["embed"], labels)


def prefill_step(params, cfg, batch, pad_to: int | None = None):
    """Inference prefill: forward over the prompt, return last-position
    logits + the primed KV cache (pos = S; seq padded to ``pad_to`` to
    leave decode headroom)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    if cfg.family == "vlm":
        vis = batch["patches"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    x, (k, v) = hidden_states(params, cfg, x, positions, collect_kv=True)
    logits = last_logits(x[:, -1], params["embed"])
    cache = {"k": pad_cache_seq(k, pad_to), "v": pad_cache_seq(v, pad_to),
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (uniform cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_spec(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def decode_step(params, cfg, cache, tokens):
    """One decode step. tokens: [B, 1] → (logits [B, V], new cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    flags = _window_flags(cfg)
    if flags is None:
        flags = jnp.ones((cfg.n_layers,), bool)
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    S = cache["k"].shape[2]

    def body(x, xs):
        lp, kc, vc, flag = xs
        xa = norm(x, lp["attn_norm"], cfg)
        q = linear(xa, lp["attn"]["wq"], lp["attn"].get("bq")).reshape(B, 1, H, Dh)
        k = linear(xa, lp["attn"]["wk"], lp["attn"].get("bk")).reshape(B, 1, KV, Dh)
        v = linear(xa, lp["attn"]["wv"], lp["attn"].get("bv")).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        from .sp_decode import seqpar_update_and_attend
        lo = jnp.zeros((), jnp.int32)
        if cfg.sliding_window is not None:
            lo = pos + 1 - cfg.sliding_window
            lo = jnp.where(flag, jnp.maximum(lo, 0), 0)
        out, kc, vc = seqpar_update_and_attend(q, kc, vc, k, v, pos, lo=lo)
        out = linear(out.reshape(B, 1, H * Dh), lp["attn"]["wo"],
                     lp["attn"].get("bo"))
        x = x + out
        x = x + _mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"], cfg)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], flags))
    x = norm(x, params["final_norm"], cfg)
    logits = last_logits(x[:, 0], params["embed"])
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}


# ---------------------------------------------------------------------------
# gemma3 long-context decode: ring-buffer local KV, full global KV
# ---------------------------------------------------------------------------

def init_longctx_cache(cfg, batch: int, max_len: int):
    spec = longctx_cache_spec(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def longctx_cache_spec(cfg, batch: int, max_len: int):
    assert cfg.sliding_window and cfg.global_every
    dtype = jnp.dtype(cfg.dtype)
    L, ge, W = cfg.n_layers, cfg.global_every, cfg.sliding_window
    n_global = L // ge
    n_local = L - n_global
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "local_k": jax.ShapeDtypeStruct((n_local, batch, W, KV, Dh), dtype),
        "local_v": jax.ShapeDtypeStruct((n_local, batch, W, KV, Dh), dtype),
        "global_k": jax.ShapeDtypeStruct((n_global, batch, max_len, KV, Dh), dtype),
        "global_v": jax.ShapeDtypeStruct((n_global, batch, max_len, KV, Dh), dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_step_longctx(params, cfg, cache, tokens):
    """One decode step with ring-buffer local caches (gemma3 @ 500k).

    Layers unrolled in Python (heterogeneous cache shapes preclude scan);
    L is small (26) so the HLO stays modest.
    """
    B = tokens.shape[0]
    ge, W = cfg.global_every, cfg.sliding_window
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    new_cache = dict(cache)
    lk, lv = cache["local_k"], cache["local_v"]
    gk, gv = cache["global_k"], cache["global_v"]
    i_loc = i_glob = 0
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a, i=layer: a[i], params["layers"])
        is_global = (layer % ge) == (ge - 1)
        xa = norm(x, lp["attn_norm"], cfg)
        q = linear(xa, lp["attn"]["wq"], lp["attn"].get("bq")).reshape(B, 1, H, Dh)
        k = linear(xa, lp["attn"]["wk"], lp["attn"].get("bk")).reshape(B, 1, KV, Dh)
        v = linear(xa, lp["attn"]["wv"], lp["attn"].get("bv")).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if is_global:
            from .sp_decode import seqpar_update_and_attend
            out, kc, vc = seqpar_update_and_attend(
                q, gk[i_glob], gv[i_glob], k, v, pos)
            gk = gk.at[i_glob].set(kc)
            gv = gv.at[i_glob].set(vc)
            i_glob += 1
        else:
            slot = pos % W
            kc, vc = update_kv_cache(lk[i_loc], lv[i_loc], k, v, slot)
            lk = lk.at[i_loc].set(kc)
            lv = lv.at[i_loc].set(vc)
            out = decode_attention(q, kc, vc, jnp.minimum(pos + 1, W))
            i_loc += 1
        out = linear(out.reshape(B, 1, H * Dh), lp["attn"]["wo"],
                     lp["attn"].get("bo"))
        x = x + out
        x = x + _mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"], cfg)
    x = norm(x, params["final_norm"], cfg)
    new_cache.update(local_k=lk, local_v=lv, global_k=gk, global_v=gv,
                     pos=pos + 1)
    return last_logits(x[:, 0], params["embed"]), new_cache
