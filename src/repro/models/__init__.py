"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM families."""
from . import model_zoo  # noqa: F401
from .model_zoo import (cache_spec, count_params, decode_step,  # noqa: F401
                        init_cache, init_params, input_specs, loss_fn)
