"""Zamba-2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The shared transformer block (single weight set) is applied after every
``shared_attn_every`` SSM layers — zamba2-2.7b: 54 Mamba-2 layers in 9
groups of 6, 9 invocations of the shared block. Each invocation has its
own KV cache at decode time (different depths see different streams).

Simplifications vs. the released checkpoint (DESIGN.md §9): no per-
invocation LoRA deltas on the shared block and plain residual (no
concat-with-embedding) — dims and FLOP structure match the config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention, attn_params, decode_attention,
                     dense_init, linear, mlp_params, shard_act, swiglu_mlp)
from .lm_common import (chunked_xent, embed_tokens, last_logits, norm,
                        norm_params, pad_cache_seq, shift_labels,
                        update_kv_cache)
from .mamba2 import _layer_init as _mamba_layer_init
from .mamba2 import _dims, mamba_block, mamba_step
from .transformer import _remat


def _n_groups(cfg):
    assert cfg.n_layers % cfg.shared_attn_every == 0
    return cfg.n_layers // cfg.shared_attn_every


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_l, k_s = jax.random.split(key, 3)
    n_g, k_per = _n_groups(cfg), cfg.shared_attn_every
    layers = jax.vmap(lambda k: _mamba_layer_init(k, cfg, dtype))(
        jax.random.split(k_l, cfg.n_layers))
    # reshape stacked leaves to [n_groups, per_group, ...]
    layers = jax.tree.map(
        lambda a: a.reshape(n_g, k_per, *a.shape[1:]), layers)
    ks = jax.random.split(k_s, 2)
    shared = {
        "attn_norm": norm_params(cfg, dtype),
        "attn": attn_params(ks[0], cfg, dtype),
        "mlp_norm": norm_params(cfg, dtype),
        "mlp": mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": dense_init(k_e, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "layers": layers,
        "shared": shared,
        "final_norm": norm_params(cfg, dtype),
    }


def hidden_states(params, cfg, x, positions):
    shared = params["shared"]

    def group_body(x, glp):
        def inner(x, lp):
            x = x + mamba_block(norm(x, lp["norm"], cfg), lp, cfg)
            return shard_act(x, "btd"), None

        x, _ = jax.lax.scan(inner, x, glp)
        h = attention(norm(x, shared["attn_norm"], cfg), shared["attn"], cfg,
                      positions=positions, causal=True)
        x = x + h
        x = x + swiglu_mlp(norm(x, shared["mlp_norm"], cfg), shared["mlp"])
        return shard_act(x, "btd"), None

    group_body = _remat(group_body, cfg)
    x, _ = jax.lax.scan(group_body, x, params["layers"])
    return norm(x, params["final_norm"], cfg)


def loss_fn(params, cfg, batch):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    x = hidden_states(params, cfg, x, positions)
    return chunked_xent(x, params["embed"], shift_labels(tokens))


def prefill_step(params, cfg, batch, pad_to: int | None = None):
    """Prefill → (last logits, cache): O(1) SSM states + per-invocation
    KV caches for the shared attention block."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    shared = params["shared"]

    def group_body(x, glp):
        def inner(x, lp):
            y, (conv, h) = mamba_block(norm(x, lp["norm"], cfg), lp, cfg,
                                       return_state=True)
            return shard_act(x + y, "btd"), (conv, h)

        x, (conv, h) = jax.lax.scan(inner, x, glp)
        out, (k, v) = attention(norm(x, shared["attn_norm"], cfg),
                                shared["attn"], cfg, positions=positions,
                                causal=True, return_kv=True)
        x = x + out
        x = x + swiglu_mlp(norm(x, shared["mlp_norm"], cfg), shared["mlp"])
        return shard_act(x, "btd"), (conv, h, k, v)

    group_body = _remat(group_body, cfg)
    x, (conv, h, k, v) = jax.lax.scan(group_body, x, params["layers"])
    x = norm(x, params["final_norm"], cfg)
    logits = last_logits(x[:, -1], params["embed"])
    dtype = jnp.dtype(cfg.dtype)
    return logits, {"conv": conv.astype(dtype), "h": h,
                    "k": pad_cache_seq(k.astype(dtype), pad_to),
                    "v": pad_cache_seq(v.astype(dtype), pad_to),
                    "pos": jnp.asarray(S, jnp.int32)}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch: int, max_len: int):
    s, d_in, H, d_xbc = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    n_g, k_per = _n_groups(cfg), cfg.shared_attn_every
    return {
        "conv": jax.ShapeDtypeStruct(
            (n_g, k_per, batch, s.d_conv - 1, d_xbc), dtype),
        "h": jax.ShapeDtypeStruct(
            (n_g, k_per, batch, H, s.head_dim, s.d_state), jnp.float32),
        "k": jax.ShapeDtypeStruct(
            (n_g, batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jax.ShapeDtypeStruct(
            (n_g, batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                        cache_spec(cfg, batch, max_len))


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)[:, 0]   # [B, D]
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    shared = params["shared"]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def group_body(x, xs):
        glp, conv, h, kc, vc = xs

        def inner(x, ys):
            lp, cs, hs = ys
            y, cs, hs = mamba_step(norm(x, lp["norm"], cfg), lp, cfg, cs, hs)
            return x + y, (cs, hs)

        x, (conv, h) = jax.lax.scan(inner, x, (glp, conv, h))
        xa = norm(x[:, None], shared["attn_norm"], cfg)
        q = linear(xa, shared["attn"]["wq"]).reshape(B, 1, H, Dh)
        k = linear(xa, shared["attn"]["wk"]).reshape(B, 1, KV, Dh)
        v = linear(xa, shared["attn"]["wv"]).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        from .sp_decode import seqpar_update_and_attend
        out, kc, vc = seqpar_update_and_attend(
            q[:, :], kc, vc, k, v, pos)
        x = x + linear(out.reshape(B, H * Dh), shared["attn"]["wo"])
        x = x + swiglu_mlp(norm(x, shared["mlp_norm"], cfg), shared["mlp"])
        return x, (conv, h, kc, vc)

    x, (conv_n, h_n, k_n, v_n) = jax.lax.scan(
        group_body, x,
        (params["layers"], cache["conv"], cache["h"], cache["k"], cache["v"]))
    x = norm(x, params["final_norm"], cfg)
    return last_logits(x, params["embed"]), {
        "conv": conv_n, "h": h_n, "k": k_n, "v": v_n, "pos": pos + 1}
