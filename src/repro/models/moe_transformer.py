"""MoE decoder transformer (qwen3-moe, phi3.5-moe) with CG routing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.moe.layer import init_moe_params, moe_ffn

from .layers import (apply_rope, attention, attn_params, decode_attention,
                     dense_init, linear, shard_act)
from .lm_common import (chunked_xent, embed_tokens, last_logits, norm,
                        norm_params, pad_cache_seq, shift_labels,
                        update_kv_cache)
from .transformer import _remat, cache_spec, init_cache  # noqa: F401 (reuse)

AUX_COEF = 0.01
Z_COEF = 1e-3


def _layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": norm_params(cfg, dtype),
        "attn": attn_params(ks[0], cfg, dtype),
        "mlp_norm": norm_params(cfg, dtype),
        "moe": init_moe_params(ks[1], cfg, dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_l = jax.random.split(key)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(
        jax.random.split(k_l, cfg.n_layers))
    return {
        "embed": dense_init(k_e, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "layers": layers,
        "final_norm": norm_params(cfg, dtype),
    }


def hidden_states(params, cfg, x, positions, collect_kv: bool = False):
    """Returns (x, aux, z, route_metrics[, kvs]) — route_metrics carries
    the CG-routing telemetry summed over layers (drop fraction, mean
    per-expert load [E], worst load/cap_e utilization)."""
    E = cfg.moe.n_experts

    def body(carry, lp):
        x, aux, z, drop, load, maxl = carry
        h, kv = attention(norm(x, lp["attn_norm"], cfg), lp["attn"], cfg,
                          positions=positions, causal=True,
                          window=cfg.sliding_window, return_kv=True)
        x = x + h
        h, m = moe_ffn(norm(x, lp["mlp_norm"], cfg), lp["moe"], cfg)
        x = x + h
        return ((shard_act(x, "btd"), aux + m["aux_loss"], z + m["z_loss"],
                 drop + m["drop_frac"], load + m["load"],
                 jnp.maximum(maxl, m["max_load_frac"])),
                (kv if collect_kv else None))

    body = _remat(body, cfg)
    (x, aux, z, drop, load, maxl), kvs = jax.lax.scan(
        body, (x, jnp.float32(0), jnp.float32(0), jnp.float32(0),
               jnp.zeros((E,), jnp.float32), jnp.float32(0)),
        params["layers"])
    x = norm(x, params["final_norm"], cfg)
    rm = {"drop_frac": drop / cfg.n_layers,
          "load": load / cfg.n_layers,
          "max_load_frac": maxl}
    if collect_kv:
        return x, aux, z, rm, kvs
    return x, aux, z, rm


def loss_fn(params, cfg, batch, with_metrics: bool = False):
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    x, aux, z, rm = hidden_states(params, cfg, x, positions)
    labels = shift_labels(tokens)
    ce = chunked_xent(x, params["embed"], labels)
    loss = ce + AUX_COEF * aux / cfg.n_layers + Z_COEF * z / cfg.n_layers
    if with_metrics:
        return loss, {"moe_drop_frac": rm["drop_frac"],
                      "moe_max_load_frac": rm["max_load_frac"],
                      "moe_load": rm["load"]}
    return loss


def prefill_step(params, cfg, batch, pad_to: int | None = None):
    """Inference prefill → (last logits, primed KV cache)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    x, _, _, _, (k, v) = hidden_states(params, cfg, x, positions,
                                       collect_kv=True)
    logits = last_logits(x[:, -1], params["embed"])
    return logits, {"k": pad_cache_seq(k, pad_to),
                    "v": pad_cache_seq(v, pad_to),
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params, cfg, cache, tokens):
    """One decode step. tokens: [B, 1] → (logits [B, V], new cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def body(x, xs):
        lp, kc, vc = xs
        xa = norm(x, lp["attn_norm"], cfg)
        q = linear(xa, lp["attn"]["wq"], lp["attn"].get("bq")).reshape(B, 1, H, Dh)
        k = linear(xa, lp["attn"]["wk"], lp["attn"].get("bk")).reshape(B, 1, KV, Dh)
        v = linear(xa, lp["attn"]["wv"], lp["attn"].get("bv")).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        from .sp_decode import seqpar_update_and_attend
        out, kc, vc = seqpar_update_and_attend(q, kc, vc, k, v, pos)
        out = linear(out.reshape(B, 1, H * Dh), lp["attn"]["wo"],
                     lp["attn"].get("bo"))
        x = x + out
        # decode: whole batch is a single token group (no cross-token
        # contention — capacity max(1, cf·k/E) ≥ 1 per token slot)
        h, _ = moe_ffn(norm(x, lp["mlp_norm"], cfg).reshape(1, B, -1),
                       lp["moe"], cfg)
        x = x + h.reshape(B, 1, -1)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = norm(x, params["final_norm"], cfg)
    logits = last_logits(x[:, 0], params["embed"])
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
