"""Whisper-style encoder-decoder (audio family).

Per the assignment spec, the conv frontend is a **stub**: the model
consumes precomputed frame embeddings [B, S_enc, d_model] directly
(``input_specs`` provides them). Encoder: bidirectional self-attention;
decoder: causal self-attention + cross-attention. LayerNorm + GELU MLP +
biases, per the Whisper architecture; RoPE replaces learned positional
embeddings (TPU-idiomatic adaptation, DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention, attn_params, decode_attention,
                     dense_init, gelu_mlp, linear, shard_act)
from .lm_common import (chunked_xent, embed_tokens, last_logits, norm,
                        norm_params, pad_cache_seq, shift_labels,
                        update_kv_cache)
from .transformer import _remat


def _gelu_mlp_params(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"w1": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
            "b1": jnp.zeros((cfg.d_ff,), dtype),
            "w2": dense_init(ks[1], (cfg.d_ff, cfg.d_model), dtype),
            "b2": jnp.zeros((cfg.d_model,), dtype)}


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"attn_norm": norm_params(cfg, dtype),
            "attn": attn_params(ks[0], cfg, dtype),
            "mlp_norm": norm_params(cfg, dtype),
            "mlp": _gelu_mlp_params(ks[1], cfg, dtype)}


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"self_norm": norm_params(cfg, dtype),
            "self_attn": attn_params(ks[0], cfg, dtype),
            "cross_norm": norm_params(cfg, dtype),
            "cross_attn": attn_params(ks[1], cfg, dtype),
            "mlp_norm": norm_params(cfg, dtype),
            "mlp": _gelu_mlp_params(ks[2], cfg, dtype)}


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_enc, k_dec = jax.random.split(key, 3)
    return {
        "embed": dense_init(k_e, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.n_enc_layers)),
        "enc_norm": norm_params(cfg, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
            jax.random.split(k_dec, cfg.n_layers)),
        "final_norm": norm_params(cfg, dtype),
    }


def encode(params, cfg, frames):
    """frames: [B, S_enc, D] stub embeddings → encoder memory."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])

    def body(x, lp):
        h = attention(norm(x, lp["attn_norm"], cfg), lp["attn"], cfg,
                      positions=positions, causal=False)
        x = x + h
        x = x + gelu_mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"])
        return shard_act(x, "btd"), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm(x, params["enc_norm"], cfg)


def decode_train(params, cfg, tokens, memory):
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    B, Sm = memory.shape[0], memory.shape[1]

    def body(x, lp):
        h = attention(norm(x, lp["self_norm"], cfg), lp["self_attn"], cfg,
                      positions=positions, causal=True)
        x = x + h
        mk = linear(memory, lp["cross_attn"]["wk"],
                    lp["cross_attn"].get("bk")).reshape(B, Sm, KV, Dh)
        mv = linear(memory, lp["cross_attn"]["wv"],
                    lp["cross_attn"].get("bv")).reshape(B, Sm, KV, Dh)
        h = attention(norm(x, lp["cross_norm"], cfg), lp["cross_attn"], cfg,
                      positions=positions, causal=False, kv_override=(mk, mv))
        x = x + h
        x = x + gelu_mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"])
        return shard_act(x, "btd"), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return norm(x, params["final_norm"], cfg)


def loss_fn(params, cfg, batch):
    """batch: {"frames": [B, S_enc, D], "tokens": [B, S_dec]}."""
    memory = encode(params, cfg, batch["frames"])
    x = decode_train(params, cfg, batch["tokens"], memory)
    return chunked_xent(x, params["embed"], shift_labels(batch["tokens"]))


def prefill_step(params, cfg, batch, pad_to: int | None = None):
    """Prefill: encode frames, prime cross KV, run the decoder prompt
    collecting self-KV → (last logits, cache)."""
    memory = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x = shard_act(x, "btd")
    B, S = tokens.shape
    Sm = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    KV, Dh = cfg.n_kv_heads, cfg.d_head

    def body(x, lp):
        h, kv = attention(norm(x, lp["self_norm"], cfg), lp["self_attn"],
                          cfg, positions=positions, causal=True,
                          return_kv=True)
        x = x + h
        mk = linear(memory, lp["cross_attn"]["wk"],
                    lp["cross_attn"].get("bk")).reshape(B, Sm, KV, Dh)
        mv = linear(memory, lp["cross_attn"]["wv"],
                    lp["cross_attn"].get("bv")).reshape(B, Sm, KV, Dh)
        h = attention(norm(x, lp["cross_norm"], cfg), lp["cross_attn"], cfg,
                      positions=positions, causal=False, kv_override=(mk, mv))
        x = x + h
        x = x + gelu_mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"])
        return shard_act(x, "btd"), (kv[0], kv[1], mk, mv)

    body = _remat(body, cfg)
    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = norm(x, params["final_norm"], cfg)
    logits = last_logits(x[:, -1], params["embed"])
    dtype = jnp.dtype(cfg.dtype)
    return logits, {"k": pad_cache_seq(k.astype(dtype), pad_to),
                    "v": pad_cache_seq(v.astype(dtype), pad_to),
                    "cross_k": ck.astype(dtype), "cross_v": cv.astype(dtype),
                    "pos": jnp.asarray(S, jnp.int32)}


# ---------------------------------------------------------------------------
# Decode: self KV cache + precomputed cross KV
# ---------------------------------------------------------------------------

def cache_spec(cfg, batch: int, max_len: int, enc_len: int | None = None):
    enc_len = enc_len or max_len
    dtype = jnp.dtype(cfg.dtype)
    L, KV, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, KV, Dh), dtype),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, KV, Dh), dtype),
        "cross_k": jax.ShapeDtypeStruct((L, batch, enc_len, KV, Dh), dtype),
        "cross_v": jax.ShapeDtypeStruct((L, batch, enc_len, KV, Dh), dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg, batch: int, max_len: int, enc_len: int | None = None):
    return jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype),
                        cache_spec(cfg, batch, max_len, enc_len))


def prime_cross_cache(params, cfg, cache, memory):
    """Precompute per-layer cross K/V from encoder memory."""
    B, Sm = memory.shape[0], memory.shape[1]
    KV, Dh = cfg.n_kv_heads, cfg.d_head

    def per_layer(lp):
        mk = linear(memory, lp["cross_attn"]["wk"],
                    lp["cross_attn"].get("bk")).reshape(B, Sm, KV, Dh)
        mv = linear(memory, lp["cross_attn"]["wv"],
                    lp["cross_attn"].get("bv")).reshape(B, Sm, KV, Dh)
        return mk, mv

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "cross_k": ck.astype(cache["cross_k"].dtype),
            "cross_v": cv.astype(cache["cross_v"].dtype)}


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    enc_len = cache["cross_k"].shape[2]

    def body(x, xs):
        lp, kc, vc, ck, cv = xs
        xa = norm(x, lp["self_norm"], cfg)
        q = linear(xa, lp["self_attn"]["wq"], lp["self_attn"].get("bq")).reshape(B, 1, H, Dh)
        k = linear(xa, lp["self_attn"]["wk"], lp["self_attn"].get("bk")).reshape(B, 1, KV, Dh)
        v = linear(xa, lp["self_attn"]["wv"], lp["self_attn"].get("bv")).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        from .sp_decode import seqpar_update_and_attend
        out, kc, vc = seqpar_update_and_attend(q, kc, vc, k, v, pos)
        x = x + linear(out.reshape(B, 1, H * Dh), lp["self_attn"]["wo"],
                       lp["self_attn"].get("bo"))
        xa = norm(x, lp["cross_norm"], cfg)
        q = linear(xa, lp["cross_attn"]["wq"], lp["cross_attn"].get("bq")).reshape(B, 1, H, Dh)
        from .sp_decode import seqpar_attend
        out = seqpar_attend(q, ck, cv, enc_len)
        x = x + linear(out.reshape(B, 1, H * Dh), lp["cross_attn"]["wo"],
                       lp["cross_attn"].get("bo"))
        x = x + gelu_mlp(norm(x, lp["mlp_norm"], cfg), lp["mlp"])
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = norm(x, params["final_norm"], cfg)
    return last_logits(x[:, 0], params["embed"]), {
        **cache, "k": k_new, "v": v_new, "pos": pos + 1}
