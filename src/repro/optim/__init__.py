from .adamw import AdamWConfig, global_norm, init, init_specs, schedule, update  # noqa: F401
