"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Optimizer states inherit the parameters' 2-D (FSDP × TP) sharding, so
ZeRO-1 comes for free: each chip holds 1/(data·model) of m/v/master.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "master": master,
            "step": jnp.zeros((), jnp.int32)}


def init_specs(param_specs, scalar_spec):
    """Opt-state PartitionSpecs mirroring the param specs."""
    return {"m": param_specs, "v": param_specs, "master": param_specs,
            "step": scalar_spec}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    g_norm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(g_norm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mw):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / c1, v / c2
        mw = mw - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mw)
        return m, v, mw

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    m_new = treedef.unflatten([o[0] for o in out])
    v_new = treedef.unflatten([o[1] for o in out])
    w_new = treedef.unflatten([o[2] for o in out])
    params_new = jax.tree.map(
        lambda mw, p: mw.astype(p.dtype), w_new, params)
    return params_new, {"m": m_new, "v": v_new, "master": w_new,
                        "step": step}, {"lr": lr, "grad_norm": g_norm}
