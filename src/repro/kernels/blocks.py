"""Shared block-engine math: one implementation for jnp ref + Pallas.

Everything here is the snapshot-probing block engine's inner math —
candidate resolution against a frozen load snapshot, the heavy-hitter
budget schedule, the count-min sketch, and the capacity schedule — in a
form that traces identically inside a ``jax.lax.scan`` body (the jnp
reference engines in ``kernels/ref.py``) and inside a Pallas kernel
body (``kernels/porc_snapshot.py``). The Pallas engines call these
exact functions, which is what makes kernel-vs-ref bit-identity a
structural property instead of a test-enforced aspiration.

Kernel-traceability rules this module obeys (a Pallas kernel body
cannot close over concrete device arrays):

* no module-level jnp constants — scalars are plain Python ints/floats
  wrapped with ``jnp.uint32(...)``/float ops at the call site;
* no non-zero-start ``jnp.arange`` (it constant-folds to a concrete
  array; start-0 arange lowers to ``lax.iota`` and is fine) — salted
  probe chains come from :func:`probe_salts` instead.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_to_bins


def probe_salts(count: int, start: int = 1) -> jnp.ndarray:
    """Salts ``start .. start+count-1`` as uint32 (Alg. 1: salt <- 1).

    Equals ``jnp.arange(start, start + count, dtype=uint32)`` but built
    from ``lax.iota`` so it traces inside a Pallas kernel body instead
    of constant-folding to a captured device array.
    """
    return jax.lax.iota(jnp.uint32, count) + jnp.uint32(start)


# ---------------------------------------------------------------------------
# Capacity schedule
# ---------------------------------------------------------------------------
# Both engines must evaluate the *same float expression* — float32
# addition/division are not associative, so a mathematically equal
# rearrangement would break bit-identity between ref and kernel.

def snapshot_cap(eps: float, n_bins: int, m0, b, block: int):
    """Single-source capacity at the end of block ``b``:
    (1+eps)·m_t/n with m_t = m0 + (b+1)·block."""
    return (1.0 + eps) * (m0 + (b + 1.0) * block) / n_bins


def view_cap(eps: float, n_bins: int, mass, lookahead: float):
    """Per-source capacity from the local-view mass (multisource §V-C):
    (1+eps)·(mass + lookahead)/n with lookahead the source's share of
    the arriving block (block/S; 1/S for the ragged tail)."""
    return (1.0 + eps) * (mass + lookahead) / n_bins


# ---------------------------------------------------------------------------
# Snapshot probing (the plain engine)
# ---------------------------------------------------------------------------

def snapshot_resolve(load, cap, cand, salts, assign, max_probes):
    """First under-cap candidate per key, respecting the probe ceiling."""
    ok = (load[cand] < cap) & (salts <= max_probes)[None, :]
    first = jnp.argmax(ok, axis=1)
    pick = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
    hit = (assign < 0) & jnp.any(ok, axis=1)
    return jnp.where(hit, pick, assign)


def snapshot_block(load, cap, kblk, cand0, n_bins: int, block: int,
                   chunk: int):
    """Route one block of keys against a frozen load snapshot.

    The single routing semantics shared by ``ref_porc_snapshot`` (one
    source, snapshot = running load) and ``ref_porc_multisource`` (one
    snapshot per source = merged base + own delta): each key walks its
    salted-probe chain against ``load`` and stops at the first bin below
    ``cap``. At block=1 the full 4·n_bins chain of Alg. 1 runs (lazily,
    in chunks of ``chunk`` salts); at block>1 the budget is the ``chunk``
    pre-hashed candidates in ``cand0``. Exhausting the budget falls back
    to the least-loaded snapshot bin (Alg. 1's fallback).
    """
    max_probes = 4 * n_bins
    salts0 = probe_salts(chunk)
    assign = snapshot_resolve(load, cap, cand0, salts0,
                              jnp.full((block,), -1, jnp.int32), max_probes)

    if block == 1:
        # exactness: continue the salted chain to the oracle ceiling
        def cond(c):
            salt0, assign = c
            return (salt0 <= max_probes) & jnp.any(assign < 0)

        def probe_chunk(c):
            salt0, assign = c
            salts = salt0 + jax.lax.iota(jnp.uint32, chunk)
            cand = hash_to_bins(kblk[:, None], salts[None, :], n_bins)
            return salt0 + chunk, snapshot_resolve(load, cap, cand, salts,
                                                   assign, max_probes)

        _, assign = jax.lax.while_loop(
            cond, probe_chunk, (jnp.uint32(1 + chunk), assign))

    # probe budget exhausted: least-loaded snapshot bin (Alg. 1)
    return jnp.where(assign < 0, jnp.argmin(load).astype(jnp.int32), assign)


# ---------------------------------------------------------------------------
# Heavy-hitter-aware probe depth — D-Choices / W-Choices
# (arXiv:1510.05714 "When Two Choices Are not Enough")
# ---------------------------------------------------------------------------

class HHPolicy(NamedTuple):
    """Static per-key probe-depth policy driven by a count-min sketch.

    PoRC gives every key the same probe budget; at scale the few heavy
    keys need *many* choices while the long tail needs only two — that
    is what bounds imbalance and replication simultaneously. The policy
    classifies each key against a device-resident count-min sketch at
    the block boundary (snapshot semantics, like the load itself) and
    assigns a per-key probe budget:

    * **tail** (estimate < ``hot_fraction`` · routed mass): ``d_tail``
      salted choices; on cap exhaustion the key falls back to the
      least-loaded bin *among its own candidates* (PKG-style), so a
      tail key is ever stored on at most ``d_tail`` bins.
    * **heavy**: the probe-depth schedule
      ``d_tail + ceil(headroom · p̂ · n/(1+eps))`` — the Eq.-2 minimum
      spread a key of estimated share p̂ needs, with slack — clipped to
      ``d_heavy`` under scheme ``"d"`` (D-Choices) or to ``n_bins``
      under ``"w"`` (W-Choices: the full choice set).

    A key whose budget exceeds the materialized candidate chain is
    entitled to more choices than were hashed: it falls back to the
    *full* choice set (the least-loaded bins, spread in load order so a
    hot key's block never piles onto a single bin;
    ``spread_fallback=False`` keeps the plain engine's single-argmin
    fallback instead). That rule makes the *neutral* policy —
    ``hot_fraction >= 1`` (threshold off) with ``d_tail`` above the
    chain length and ``spread_fallback=False`` — bit-identical to the
    plain snapshot engine at block > 1: the CI parity gate.

    All fields are Python scalars, so the policy is hashable and rides
    as a static jit argument; ``None`` policy compiles to exactly the
    sketch-free engine.
    """
    scheme: str = "d"            # "d": heavy depth capped at d_heavy;
                                 # "w": cap lifted to n_bins (full set)
    depth: int = 4               # sketch rows (independent hashes)
    width: int = 4096            # sketch columns per row; keep width
                                 # >= ~4/hot_fraction so collision noise
                                 # (~m/width per row) stays below the
                                 # heavy threshold
    hot_fraction: float = 1e-3   # heavy when est >= hot_fraction * m_t
    d_heavy: int = 32            # probe-depth ceiling for heavy keys
                                 # under scheme "d"
    d_tail: int = 2              # probe budget for tail keys
    headroom: float = 2.0        # schedule slack over the Eq.-2
                                 # minimum spread ceil(p·n/(1+eps))
    chain: int = 0               # materialized candidates per key; 0 =
                                 # auto (the scheme ceiling, so every
                                 # budget is candidate-bounded). Budgets
                                 # beyond the chain fall back to the
                                 # full choice set.
    rotate_duplicates: bool = True  # the r-th in-block duplicate of a
                                 # key starts probing at candidate r of
                                 # its window, so a hot key's block
                                 # doesn't pile onto one snapshot bin
                                 # (False: plain first-fit — parity)
    spread_fallback: bool = True # full-choice-set fallback spreads over
                                 # the least-loaded bins in load order
                                 # (False: single argmin bin — the plain
                                 # engine's fallback, the parity config)


def neutral_hh_policy(n_bins: int, **kw) -> HHPolicy:
    """The policy that routes bit-identically to the plain engine at
    block > 1 (threshold off, tail budget beyond the chain, first-fit
    order, argmin fallback) while still exercising the whole
    sketch/budget machinery — the CI parity configuration."""
    return HHPolicy(scheme="w", hot_fraction=2.0, d_tail=4 * n_bins + 1,
                    chain=1, rotate_duplicates=False,
                    spread_fallback=False, **kw)


# sketch hashes live in their own salt space, disjoint from the probe
# chain's small consecutive salts (plain Python int: kernel-traceable)
SKETCH_SALT0 = 0x5EEDC0DE


def sketch_cols(policy: HHPolicy, keys: jnp.ndarray) -> jnp.ndarray:
    salts = probe_salts(policy.depth, start=SKETCH_SALT0)
    return hash_to_bins(keys[..., None], salts, policy.width)


def hh_sketch_init(policy: HHPolicy) -> jnp.ndarray:
    """Zeroed count-min counts [depth, width]."""
    return jnp.zeros((policy.depth, policy.width), jnp.float32)


def hh_sketch_update(policy: HHPolicy, counts: jnp.ndarray,
                     keys: jnp.ndarray,
                     weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Add ``keys`` (optionally weighted) into the sketch. The sketch is
    *linear*: updating with two streams in any order — or merging two
    sketches by addition — equals updating with the concatenation,
    which is exactly why it threads through the multi-source
    delta-merge path unchanged."""
    cols = sketch_cols(policy, keys)                        # [..., depth]
    w = (jnp.ones(keys.shape, jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    return counts.at[jnp.arange(policy.depth), cols].add(w[..., None])


def hh_sketch_query(policy: HHPolicy, counts: jnp.ndarray,
                    keys: jnp.ndarray) -> jnp.ndarray:
    """Estimated count per key: min over rows (never underestimates)."""
    cols = sketch_cols(policy, keys)
    return counts[jnp.arange(policy.depth), cols].min(-1)


def hh_budgets(policy: HHPolicy, n_bins: int, eps: float,
               est: jnp.ndarray, mass) -> jnp.ndarray:
    """Per-key probe budgets: the probe-depth schedule.

    ``est`` are sketch estimates, ``mass`` the routed message mass the
    estimates are measured against (broadcastable). Tail keys get
    ``d_tail``; heavy keys get the Eq.-2-derived spread, clipped to the
    scheme's ceiling (``d_heavy`` for "d", ``n_bins`` for "w").
    """
    mass = jnp.maximum(jnp.asarray(mass, jnp.float32), 1.0)
    heavy = est >= policy.hot_fraction * mass
    need = jnp.ceil(policy.headroom * (est / mass) * n_bins / (1.0 + eps))
    ceiling = max(n_bins if policy.scheme == "w" else policy.d_heavy,
                  policy.d_tail + 1)
    bud = jnp.clip(need.astype(jnp.int32) + policy.d_tail,
                   policy.d_tail + 1, ceiling)
    return jnp.where(heavy, bud, jnp.int32(policy.d_tail))


def hh_chunk(policy: HHPolicy, chunk: int, n_bins: int) -> int:
    """Candidates to materialize per key: by default the chain covers
    the scheme's budget ceiling (``d_heavy`` for "d", ``n_bins`` for
    "w") so every policy budget is candidate-bounded — a heavy key's
    replication then stays confined to its own salted chain instead of
    leaking onto whichever bins happen to be least loaded per block.
    ``policy.chain`` overrides the ceiling (the neutral/parity config
    pins it to the plain engine's chunk)."""
    ceiling = policy.chain or (n_bins if policy.scheme == "w"
                               else policy.d_heavy)
    return max(chunk, min(ceiling, n_bins))


def snapshot_block_hh(load, cap, kblk, cand, bud, n_bins: int,
                      rotate: bool, spread: bool):
    """Route one block against a frozen snapshot with per-key budgets.

    Each key probes its salted candidates in order and stops at the
    first bin below ``cap``, exactly like ``snapshot_block``, but only
    its first ``bud[k]`` candidates are admissible. With ``rotate``,
    the r-th in-block duplicate of a key starts probing at offset r of
    its admissible window (wrapping), so a hot key's block spreads over
    its under-cap candidates instead of piling onto the first one the
    frozen snapshot shows as free. On exhaustion:
    * budget within the materialized chain → least-loaded bins among
      the key's own admissible candidates, duplicates rotated across
      the load order (bounds its replication at bud),
    * budget beyond the chain (a tail budget set past the chain — the
      neutral/parity config) → the full choice set: least-loaded bins
      spread in load order (``spread``), or the single argmin bin.
    """
    B, C = cand.shape
    idx = jnp.arange(C)
    window = jnp.minimum(bud, C)                       # admissible width
    admissible = idx[None, :] < window[:, None]
    ok = (load[cand] < cap) & admissible
    if rotate:
        i = jnp.arange(B)
        eq = kblk[:, None] == kblk[None, :]
        dup = (eq & (i[None, :] < i[:, None])).sum(1)  # in-block dup rank
        count = eq.sum(1)                              # in-block copies
        # spread the key's copies evenly across its window — adjacent
        # offsets would collide on the same first under-cap candidate
        offset = (dup * window) // jnp.maximum(count, 1)
        pos = jnp.mod(idx[None, :] - offset[:, None],
                      jnp.maximum(window[:, None], 1))
    else:
        pos = jnp.broadcast_to(idx[None, :], (B, C))
    first = jnp.argmin(jnp.where(ok, pos, C + 1), axis=1)
    pick = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
    resolved = jnp.any(ok, axis=1)
    # bounded choice set: least-loaded among the key's own candidates.
    # With rotation the tie is broken by a potential score load + pos,
    # where pos is the candidate's rotated distance from the
    # duplicate's own offset measured in messages (one step forward =
    # one message of load) — duplicates settle into *distinct* light
    # bins without the per-row sort a "dup-th least loaded" pick needs.
    loadc = jnp.where(admissible, load[cand], jnp.inf)
    fbidx = jnp.argmin(loadc + pos if rotate else loadc, axis=1)
    candmin = jnp.take_along_axis(cand, fbidx[:, None], 1)[:, 0]
    over = bud > C                       # entitled to the full choice set
    if spread:
        border = jnp.argsort(load).astype(jnp.int32)
        leftpos = jnp.cumsum((~resolved & over).astype(jnp.int32)) - 1
        globpick = border[leftpos % n_bins]
    else:
        globpick = jnp.broadcast_to(jnp.argmin(load).astype(jnp.int32), (B,))
    fallback = jnp.where(over, globpick, candmin)
    return jnp.where(resolved, pick, fallback)
