"""Pallas snapshot-probing PoRC block engine (single- and multi-source).

The fast-path semantics of ``ref.ref_porc_snapshot`` /
``ref.ref_porc_multisource`` as sequential-grid Pallas kernels: the
load vector (and, multisource, the per-source delta lanes and count-min
sketch lanes) lives in **VMEM scratch** and is carried across the grid,
so per block the only HBM traffic is the keys in and the assignments
out. Candidate hashing is *fused into the probe scan* — the salted
chain is hashed inside the kernel body right before it is resolved
against the snapshot, instead of materializing a [M, chain] candidate
tensor in HBM the way the jnp path hoists it. That fusion is what
removes the ROADMAP-flagged chain-width cost of the HH policy path: a
W-Choices chain of n_bins candidates never round-trips to memory.

Bit-identity with the jnp reference engines is structural, not
aspirational: the kernel bodies call the *same* block math
(``kernels.blocks``: ``snapshot_block``, ``snapshot_block_hh``,
``hh_budgets``, the sketch, and the shared capacity schedule
``snapshot_cap``/``view_cap``) that ``kernels/ref.py`` scans over, and
the hash family in ``core.hashing`` is written to trace inside a kernel
body. The parity tests (``tests/test_porc_snapshot_pallas.py``) and the
CI gate pin this in interpret mode; on TPU the same program compiles to
Mosaic.

Grid: (M // block,), sequential. Scratch: load [n_bins] f32 (+
delta [S, n_bins], sketch lanes when multisource / HH policy).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import hash_to_bins

from . import blocks
from .backend import resolve_engine, resolve_interpret  # noqa: F401
from .blocks import HHPolicy


# ---------------------------------------------------------------------------
# Single source — the ``ref_porc_snapshot`` kernel
# ---------------------------------------------------------------------------

def _snapshot_kernel(m0_ref, load0_ref, keys_ref, assign_ref, loadout_ref,
                     load_scr, *,
                     n_bins: int, block: int, eps: float, chunk: int,
                     n_blocks: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        load_scr[...] = load0_ref[...]

    load = load_scr[...]
    kblk = keys_ref[...]
    cap = blocks.snapshot_cap(eps, n_bins, m0_ref[0],
                              b.astype(jnp.float32), block)
    # fused candidate hashing: the first chunk of the salted chain,
    # hashed in-kernel (the jnp path hoists the same values to HBM)
    cand = hash_to_bins(kblk[:, None], blocks.probe_salts(chunk)[None, :],
                        n_bins)
    assign = blocks.snapshot_block(load, cap, kblk, cand, n_bins, block,
                                   chunk)
    assign_ref[...] = assign
    load_scr[...] = load.at[assign].add(1.0)

    @pl.when(b == n_blocks - 1)
    def _flush():
        loadout_ref[...] = load_scr[...]


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "eps",
                                             "chunk", "interpret"))
def porc_snapshot(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                  eps: float = 0.05, chunk: int = 8,
                  load0: jnp.ndarray | None = None, m0: float = 0.0,
                  interpret: bool | None = None):
    """Snapshot-probing PoRC as a Pallas kernel — drop-in for
    ``ref.ref_porc_snapshot`` (same signature, bit-identical result).

    Every block probes the frozen VMEM load snapshot with its salted
    chain (hashed in-kernel) against the capacity
    (1+eps)·m_t/n_bins at block end; at block=1 the full 4·n_bins lazy
    chain of Alg. 1 runs, so the kernel is bit-identical to the
    sequential oracle. ``interpret=None`` → auto (compiled on TPU).

    Returns (assignment [M] int32, final load [n_bins] f32).
    """
    M = keys.shape[0]
    assert M % block == 0, f"{M} % {block} != 0"
    n_blocks = M // block
    load0_arr = (jnp.zeros((n_bins,), jnp.float32) if load0 is None
                 else load0.astype(jnp.float32))
    if n_blocks == 0:
        return jnp.zeros((0,), jnp.int32), load0_arr
    kernel = functools.partial(_snapshot_kernel, n_bins=n_bins, block=block,
                               eps=eps, chunk=chunk, n_blocks=n_blocks)
    m0_arr = jnp.reshape(jnp.asarray(m0, jnp.float32), (1,))
    assign, load = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n_bins,), lambda b: (0,)),
            pl.BlockSpec((block,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((n_bins,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M,), jnp.int32),
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_bins,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(m0_arr, load0_arr, keys)
    return assign, load


# ---------------------------------------------------------------------------
# Multi-source — the ``_porc_multisource_scan`` kernel (delta + sketch
# lanes in scratch, piggyback merge on the sync cadence)
# ---------------------------------------------------------------------------

def _multisource_kernel(*refs, n_bins: int, n_sources: int, block: int,
                        sync_every: int, eps: float, chunk: int,
                        chunk_eff: int, n_blocks: int,
                        policy: HHPolicy | None):
    S = n_sources
    if policy is None:
        (ticks_ref, base0_ref, delta0_ref, keys_ref,
         assign_ref, baseout_ref, deltaout_ref,
         base_scr, delta_scr) = refs
    else:
        (ticks_ref, base0_ref, delta0_ref, skb0_ref, skd0_ref, keys_ref,
         assign_ref, baseout_ref, deltaout_ref, skbout_ref, skdout_ref,
         base_scr, delta_scr, skb_scr, skd_scr) = refs
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        base_scr[...] = base0_ref[...]
        delta_scr[...] = delta0_ref[...]
        if policy is not None:
            skb_scr[...] = skb0_ref[...]
            skd_scr[...] = skd0_ref[...]

    base, delta = base_scr[...], delta_scr[...]
    kblk = keys_ref[0]                                 # [S, block]
    # same local-view capacity as the jnp scan (see the long rationale
    # in ref._porc_multisource_scan): per-source mass, aggregate
    # lookahead of one block across the S sources
    mass = base.sum() + delta.sum(1)                   # [S]
    cap = blocks.view_cap(eps, n_bins, mass, block / S)
    views = base[None, :] + delta                      # [S, n_bins]
    # fused candidate hashing — for the policy path this chain is up to
    # n_bins wide and never leaves the kernel
    cand = hash_to_bins(kblk[..., None], blocks.probe_salts(chunk_eff),
                        n_bins)
    if policy is None:
        assign = jax.vmap(
            lambda view, c, kk, cb: blocks.snapshot_block(
                view, c, kk, cb, n_bins, block, chunk))(
            views, cap, kblk, cand)                    # [S, block]
    else:
        skb, skd = skb_scr[...], skd_scr[...]
        est = jax.vmap(
            lambda d, k: blocks.hh_sketch_query(policy, skb + d, k))(
            skd, kblk)                                 # [S, block]
        bud = blocks.hh_budgets(policy, n_bins, eps, est, mass[:, None])
        assign = jax.vmap(
            lambda view, c, kk, cb, bd: blocks.snapshot_block_hh(
                view, c, kk, cb, bd, n_bins,
                policy.rotate_duplicates, policy.spread_fallback))(
            views, cap, kblk, cand, bud)
        skd = jax.vmap(lambda d, k: blocks.hh_sketch_update(policy, d, k))(
            skd, kblk)
    delta = jax.vmap(lambda d, a: d.at[a].add(1.0))(delta, assign)
    # piggyback merge — phase continues from ticks across calls
    sync = ((ticks_ref[0] + b + 1) % sync_every) == 0
    base = jnp.where(sync, base + delta.sum(0), base)
    delta = jnp.where(sync, jnp.zeros_like(delta), delta)
    assign_ref[0] = assign
    base_scr[...], delta_scr[...] = base, delta
    if policy is not None:
        skb = jnp.where(sync, skb + skd.sum(0), skb)
        skd = jnp.where(sync, jnp.zeros_like(skd), skd)
        skb_scr[...], skd_scr[...] = skb, skd

    @pl.when(b == n_blocks - 1)
    def _flush():
        baseout_ref[...] = base_scr[...]
        deltaout_ref[...] = delta_scr[...]
        if policy is not None:
            skbout_ref[...] = skb_scr[...]
            skdout_ref[...] = skd_scr[...]


@functools.partial(jax.jit, static_argnames=(
    "n_bins", "n_sources", "sync_every", "block", "eps", "chunk", "policy",
    "interpret"))
def porc_multisource_scan(keys: jnp.ndarray, n_bins: int, n_sources: int,
                          sync_every: int, block: int, eps: float,
                          chunk: int, base0, delta0, ticks0,
                          skb0=None, skd0=None,
                          policy: HHPolicy | None = None,
                          interpret: bool | None = None):
    """Pallas counterpart of ``ref._porc_multisource_scan``: the core
    multi-source scan over full per-source blocks, same argument order
    and the same ``(assign, base, delta, ticks, skb, skd)`` return, so
    ``ref_porc_multisource(engine="pallas")`` swaps it in per span.

    One grid step routes one block per source against its local view
    ``base + delta[s]`` (delta lanes in VMEM scratch), merges the lanes
    every ``sync_every`` steps, and — with a ``policy`` — carries the
    count-min sketch base/delta lanes in scratch on the same cadence.
    """
    S = n_sources
    M = keys.shape[0]
    assert M % (S * block) == 0, f"{M} % {S}*{block} != 0"
    nb = M // (S * block)
    # [nb, S, block]: source s's k-th message of its b-th block
    kb = keys.reshape(nb, block, S).transpose(0, 2, 1)
    chunk_eff = (chunk if policy is None
                 else blocks.hh_chunk(policy, chunk, n_bins))
    kernel = functools.partial(
        _multisource_kernel, n_bins=n_bins, n_sources=S, block=block,
        sync_every=sync_every, eps=eps, chunk=chunk, chunk_eff=chunk_eff,
        n_blocks=nb, policy=policy)
    ticks_arr = jnp.reshape(jnp.asarray(ticks0, jnp.int32), (1,))
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((n_bins,), lambda b: (0,)),
        pl.BlockSpec((S, n_bins), lambda b: (0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, S, block), lambda b: (b, 0, 0)),
        pl.BlockSpec((n_bins,), lambda b: (0,)),
        pl.BlockSpec((S, n_bins), lambda b: (0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((nb, S, block), jnp.int32),
        jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        jax.ShapeDtypeStruct((S, n_bins), jnp.float32),
    ]
    scratch = [pltpu.VMEM((n_bins,), jnp.float32),
               pltpu.VMEM((S, n_bins), jnp.float32)]
    operands = [ticks_arr, base0, delta0]
    if policy is not None:
        D, W = policy.depth, policy.width
        in_specs += [pl.BlockSpec((D, W), lambda b: (0, 0)),
                     pl.BlockSpec((S, D, W), lambda b: (0, 0, 0))]
        out_specs += [pl.BlockSpec((D, W), lambda b: (0, 0)),
                      pl.BlockSpec((S, D, W), lambda b: (0, 0, 0))]
        out_shape += [jax.ShapeDtypeStruct((D, W), jnp.float32),
                      jax.ShapeDtypeStruct((S, D, W), jnp.float32)]
        scratch += [pltpu.VMEM((D, W), jnp.float32),
                    pltpu.VMEM((S, D, W), jnp.float32)]
        operands += [skb0, skd0]
    in_specs.append(pl.BlockSpec((1, S, block), lambda b: (b, 0, 0)))
    operands.append(kb)
    outs = pl.pallas_call(
        kernel, grid=(nb,),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=resolve_interpret(interpret),
    )(*operands)
    if policy is None:
        assign, base, delta = outs
        skb = skd = None
    else:
        assign, base, delta, skb, skd = outs
    # invert the round-robin interleave back to global message order
    return (assign.transpose(0, 2, 1).reshape(-1), base, delta,
            (ticks0 + nb) % sync_every, skb, skd)
