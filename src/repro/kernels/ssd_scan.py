"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the sequence is
split into chunks of Q steps. Within a chunk everything is dense
matmul (MXU): the masked-decay "attention" matrix (C·Bᵀ)⊙exp(sᵢ−sⱼ) and
its product with X. Across chunks, the [P, N] state is carried in VMEM
scratch over the sequential chunk axis of the grid — never touching HBM.

Grid: (B·H, L//Q) — rows parallel, chunks sequential (row-major grid).
Semantics match ``ref.ref_ssd_scan`` (exact sequential recurrence) to
float tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
                n_heads: int, chunk: int):
    bh = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[bh % n_heads]                       # scalar decay rate (<0)
    x = x_ref[0, 0].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)         # [Q]
    bm = b_ref[0, 0].astype(jnp.float32)          # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)          # [Q, N]
    h0 = h_ref[...]                               # [P, N]

    da = dt * a                                   # [Q], ≤ 0
    s = jnp.cumsum(da)                            # inclusive

    # intra-chunk: y_i += Σ_{j≤i} e^{s_i−s_j}·dt_j·(C_i·B_j)·x_j
    g = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # [Q, Q]
    diff = s[:, None] - s[None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    w = w * g * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)       # [Q, P]

    # inter-chunk: y_i += e^{s_i}·(C_i · h0ᵀ)
    y = y + jnp.exp(s)[:, None] * jnp.dot(
        cm, h0.T, preferred_element_type=jnp.float32)           # [Q, P]

    # state carry: h' = e^{s_Q}·h0 + Σ_j e^{s_Q−s_j}·dt_j·(x_j ⊗ B_j)
    coef = dt * jnp.exp(s[-1] - s)                              # [Q]
    h_new = jnp.exp(s[-1]) * h0 + jnp.dot(
        (x * coef[:, None]).T, bm, preferred_element_type=jnp.float32)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    h_ref[...] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool | None = None) -> jnp.ndarray:
    """Chunked SSD scan. Same signature/semantics as ref.ref_ssd_scan.

    Args:
      x:  [B, L, H, P]; dt: [B, L, H]; A: [H] (negative);
      Bm/Cm: [B, L, G, N] with H % G == 0.
    Returns y: [B, L, H, P].
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, f"L={L} % chunk={chunk} != 0"
    assert H % G == 0
    nc = L // chunk
    rep = H // G

    xt = jnp.moveaxis(x, 2, 1)                     # [B, H, L, P]
    dtt = jnp.moveaxis(dt, 2, 1)                   # [B, H, L]
    bt = jnp.moveaxis(Bm, 2, 1)                    # [B, G, L, N]
    ct = jnp.moveaxis(Cm, 2, 1)

    kernel = functools.partial(_ssd_kernel, n_heads=H, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(Bsz * H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                       # A [H]
            pl.BlockSpec((1, 1, chunk, P),
                         lambda bh, c, H=H: (bh // H, bh % H, c, 0)),
            pl.BlockSpec((1, 1, chunk),
                         lambda bh, c, H=H: (bh // H, bh % H, c)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, c, H=H, rep=rep: (bh // H, (bh % H) // rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, c, H=H, rep=rep: (bh // H, (bh % H) // rep, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bh, c, H=H: (bh // H, bh % H, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(A.astype(jnp.float32), xt, dtt, bt, ct)
    return jnp.moveaxis(y, 1, 2)                   # [B, L, H, P]
