"""Mesh-sharded multi-source PoRC — §V-C source lanes on real devices.

``ref_porc_multisource`` simulates the paper's distributed sources as a
vmap axis on one device; this module puts the same semantics on a JAX
device mesh via ``shard_map``: the mesh's ``sources`` axis owns the
per-source delta lanes (``delta [S_local, n_bins]`` per host), the
merged ``base`` view is replicated, and the delta-merge synchronization
is a ``jax.lax.psum`` across the axis — the collective the paper's
piggybacked load exchange becomes on hardware.

Exactness: per-source block routing, the local-view capacity and the
merge are the *same arithmetic* as the vmapped engine (delta counts are
integer-valued f32 well below 2^24, so the psum's different summation
order is still exact), so ``mesh_porc_multisource`` is bit-identical to
``ref_porc_multisource`` at matching ``(n_sources, sync_every, block)``
— CI gates the ``sync_every=1`` case and the tests sweep wider.

The heavy-hitter sketch lanes are not mesh-sharded yet (the policy path
stays on the vmapped engine); ``policy``-carrying state is rejected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hashing import hash_to_bins
from repro.kernels.ref import (MultiSourcePorcState, _porc_multisource_tail,
                               _snapshot_block, block_spans,
                               multisource_state_init)

SOURCES_AXIS = "sources"


def _lane_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P(SOURCES_AXIS, None))


def shard_multisource_state(state: MultiSourcePorcState, mesh
                            ) -> MultiSourcePorcState:
    """Pin the per-source lanes of ``state`` onto the mesh: ``delta``
    shards row-wise over the ``sources`` axis (host h owns sources
    ``[h·S/H, (h+1)·S/H)``), the merged ``base`` and the scalars
    replicate. Sketch lanes are not supported on the mesh."""
    if state.sketch_base is not None or state.sketch_delta is not None:
        raise NotImplementedError(
            "heavy-hitter sketch lanes are not mesh-sharded; use the "
            "vmapped ref_porc_multisource for HHPolicy routing")
    S = state.delta.shape[0]
    H = mesh.shape[SOURCES_AXIS]
    if S % H != 0:
        raise ValueError(f"n_sources={S} not divisible by the mesh's "
                         f"{H} hosts")
    rep = NamedSharding(mesh, P())
    return state._replace(
        base=jax.device_put(state.base, rep),
        delta=jax.device_put(state.delta, _lane_sharding(mesh)))


@functools.lru_cache(maxsize=None)
def _mesh_scan(mesh, n_bins: int, n_sources: int, sync_every: int,
               block: int, eps: float, chunk: int):
    """Build (and cache) the jitted shard_map program for one
    ``(mesh, shape)`` cell. The scan body is the same per-block router
    as the vmapped engine (``_snapshot_block`` over the local sources);
    only the merge differs — a psum over the mesh axis instead of a
    ``delta.sum(0)`` over the vmap axis."""
    S = n_sources

    def body(base, delta, ticks0, kb):
        # kb: [S_local, nb, block] — this host's source substreams
        salts0 = jnp.arange(1, chunk + 1, dtype=jnp.uint32)
        cand0 = hash_to_bins(kb[..., None], salts0, n_bins)

        def blk(carry, xs):
            base, delta = carry
            b, kblk, cblk = xs                     # [S_local, block], ...
            # local-view capacity, identical to the vmapped engine: each
            # source can verify its cap against base + its own delta
            # without any cross-host traffic (see ref.py for why the
            # per-source invariant telescopes to the global envelope)
            mass = base.sum() + delta.sum(1)
            cap = (1.0 + eps) * (mass + block / S) / n_bins
            views = base[None, :] + delta
            assign = jax.vmap(
                lambda view, c, kk, cb: _snapshot_block(
                    view, c, kk, cb, n_bins, block, chunk))(
                views, cap, kblk, cblk)
            delta = jax.vmap(lambda d, a: d.at[a].add(1.0))(delta, assign)
            # piggyback merge = all-reduce of the lane deltas. The psum
            # runs every block (its operand is masked out on non-sync
            # blocks); counts are integer-valued f32, so the different
            # reduction order vs delta.sum(0) is still bit-exact.
            sync = ((ticks0 + b + 1) % sync_every) == 0
            merged = jax.lax.psum(
                jnp.where(sync, delta.sum(0), jnp.zeros((n_bins,))),
                SOURCES_AXIS)
            base = jnp.where(sync, base + merged, base)
            delta = jnp.where(sync, jnp.zeros_like(delta), delta)
            return (base, delta), assign

        nb = kb.shape[1]
        (base, delta), assign = jax.lax.scan(
            blk, (base, delta),
            (jnp.arange(nb, dtype=jnp.int32), kb.transpose(1, 0, 2),
             cand0.transpose(1, 0, 2, 3)))
        return base, delta, assign.transpose(1, 0, 2)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(SOURCES_AXIS, None), P(), P(SOURCES_AXIS, None, None)),
        out_specs=(P(), P(SOURCES_AXIS, None), P(SOURCES_AXIS, None, None)),
        check_rep=False))


def mesh_porc_multisource(keys: jnp.ndarray, n_bins: int, mesh, *,
                          n_sources: int | None = None,
                          sync_every: int = 1, block: int = 128,
                          eps: float = 0.05, chunk: int = 8,
                          state: MultiSourcePorcState | None = None):
    """Route a round-robin-interleaved key stream with the source lanes
    living on ``mesh``'s ``sources`` axis.

    Drop-in for ``ref_porc_multisource`` (snapshot engine, no policy):
    message i belongs to source ``i % S``, source s lives on host
    ``s // (S/H)``, and every semantic — local views, per-source caps,
    ``sync_every``-block delta merges, power-of-two remainder spans,
    the sub-S ragged tail publishing immediately — is inherited, so the
    result is bit-identical to the vmapped engine. The ragged tail
    (fewer than S messages) routes through the vmapped tail program;
    its lane state is re-pinned to the mesh afterwards.

    Returns (assignment [M] int32 in stream order, new state with
    mesh-sharded ``delta``).
    """
    if n_sources is None:
        if state is None:
            raise ValueError("need n_sources or a state to infer it from")
        n_sources = state.delta.shape[0]
    S = n_sources
    if state is None:
        state = multisource_state_init(n_bins, S)
    state = shard_multisource_state(state, mesh)
    base, delta, routed, ticks = (state.base, state.delta, state.routed,
                                  state.ticks)
    per = keys.shape[0] // S
    r = keys.shape[0] - per * S
    keys = jnp.asarray(keys)
    parts = []
    off = 0
    for _, length, blk in block_spans(per, block):
        span = keys[off: off + length * S]
        nb = length // blk
        # [S, nb, blk]: source s's substream, blocked — the sharded axis
        # leads so shard_map splits it across hosts
        kb = span.reshape(nb, blk, S).transpose(2, 0, 1)
        scan = _mesh_scan(mesh, n_bins, S, sync_every, blk, eps, chunk)
        base, delta, assign = scan(base, delta, ticks, kb)
        ticks = (ticks + nb) % sync_every
        routed = routed + length * S
        # [S, nb, blk] -> stream order: message (b·blk + k)·S + s
        parts.append(assign.transpose(1, 2, 0).reshape(-1))
        off += length * S
    if r:
        keys_pad = jnp.concatenate(
            [keys[off:], jnp.zeros((S - r,), keys.dtype)])
        a, base, delta, _, _ = _porc_multisource_tail(
            keys_pad, n_bins, S, eps, chunk, base, delta, jnp.float32(r))
        delta = jax.device_put(delta, _lane_sharding(mesh))
        routed = routed + r
        ticks = jnp.zeros_like(ticks)        # tail publish = a merge
        parts.append(a[:r])
    if not parts:
        assign = jnp.zeros((0,), jnp.int32)
    else:
        assign = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return assign, MultiSourcePorcState(base=base, delta=delta,
                                        routed=routed, ticks=ticks)
