"""Pallas TPU kernels for the perf-critical hot spots.

porc_assign — the paper's Alg. 1 routing loop (block-synchronous).
cg_dispatch — CG MoE dispatch: capacity-bounded with overflow.
ssd_scan    — Mamba-2 SSD chunked recurrence (assigned ssm/hybrid archs).

``ops`` holds the public jit'd wrappers; ``ref`` the pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
from .ops import cg_dispatch, porc_assign, ssd_scan  # noqa: F401
