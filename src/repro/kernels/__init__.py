"""Pallas TPU kernels for the perf-critical hot spots.

porc_assign   — the paper's Alg. 1 routing loop (rank-sequential, strict cap).
porc_snapshot — the snapshot-probing block engine (the fast path), single-
                and multi-source, HH-policy aware — bit-identical to ``ref``.
cg_dispatch   — CG MoE dispatch: capacity-bounded with overflow.
ssd_scan      — Mamba-2 SSD chunked recurrence (assigned ssm/hybrid archs).

``ops`` holds the public jit'd wrappers; ``ref`` the pure-jnp oracles;
``blocks`` the block math both engine families share; ``backend`` the
engine/interpret auto-resolution.
"""
from . import backend, blocks, ops, ref  # noqa: F401
from .backend import resolve_engine  # noqa: F401
from .ops import cg_dispatch, porc_assign, porc_snapshot, ssd_scan  # noqa: F401
