"""Pallas TPU kernel: block-synchronous PoRC assignment (paper Alg. 1).

TPU adaptation (DESIGN.md §2): the per-message probe loop is replaced by
a rank-sequential / key-vectorized sweep over blocks of B keys. The load
vector lives in **VMEM scratch** and is carried across the (sequential)
TPU grid, so per block the only HBM traffic is B keys in / B assignments
out — the kernel is compute-bound on the VPU one-hot cumsums and never
re-reads loads from HBM.

Semantics are bit-identical to ``ref.ref_porc_assign``.

Grid: (M // block,), sequential. Scratch: load [n_bins] f32.
Block shapes are (block,) for keys/assignments and the full [n_bins]
load tail output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret

def _mix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _hash_to_bins(key, salt, n_bins):
    k = key.astype(jnp.uint32)
    s = salt.astype(jnp.uint32)
    h = _mix32(k + s * jnp.uint32(0x9E3779B9))
    h = _mix32(h ^ (s * jnp.uint32(0x7F4A7C15) + jnp.uint32(0x165667B1)))
    return (h % jnp.uint32(n_bins)).astype(jnp.int32)


def _porc_kernel(m0_ref, load0_ref, keys_ref, assign_ref, loadout_ref,
                 load_ref, *,
                 n_bins: int, d: int, block: int, eps: float, n_blocks: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        load_ref[...] = load0_ref[...]

    keys = keys_ref[...]
    load = load_ref[...]
    cap = (1.0 + eps) * (m0_ref[0] + (b.astype(jnp.float32) + 1.0) * block) / n_bins

    assign = jnp.full((block,), -1, jnp.int32)
    unassigned = jnp.ones((block,), bool)
    bins = jnp.arange(n_bins, dtype=jnp.int32)

    def cond(carry):
        r, load, assign, unassigned = carry
        return (r < d) & jnp.any(unassigned)

    def rank_step(carry):
        r, load, assign, unassigned = carry
        c = _hash_to_bins(keys, (r + 1).astype(jnp.uint32), n_bins)
        onehot = (c[:, None] == bins[None, :]) & unassigned[:, None]
        oh = onehot.astype(jnp.float32)
        pos = jnp.cumsum(oh, axis=0) - oh
        mypos = jnp.sum(pos * oh, axis=1)      # pos at own bin (one-hot select)
        myload = jnp.sum(load[None, :] * oh, axis=1)
        accept = unassigned & (myload + mypos < cap)
        assign = jnp.where(accept, c, assign)
        load = load + jnp.sum(oh * accept[:, None].astype(jnp.float32), axis=0)
        return r + 1, load, assign, unassigned & ~accept

    _, load, assign, unassigned = jax.lax.while_loop(
        cond, rank_step, (jnp.int32(0), load, assign, unassigned))

    # forced fallback at probe ceiling: round-robin over least-loaded bins
    order = jnp.argsort(load).astype(jnp.int32)
    leftpos = jnp.cumsum(unassigned.astype(jnp.int32)) - 1
    fallback = order[leftpos % n_bins]
    assign = jnp.where(unassigned, fallback, assign)
    forced = (fallback[:, None] == bins[None, :]) & unassigned[:, None]
    load = load + jnp.sum(forced.astype(jnp.float32), axis=0)

    assign_ref[...] = assign
    load_ref[...] = load

    @pl.when(b == n_blocks - 1)
    def _flush():
        loadout_ref[...] = load_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("n_bins", "d", "block", "eps", "interpret"))
def porc_assign(keys: jnp.ndarray, n_bins: int, *, d: int | None = None,
                block: int = 128, eps: float = 0.05, m0: float = 0.0,
                load0: jnp.ndarray | None = None,
                interpret: bool | None = None):
    """Block-synchronous PoRC over a key stream.

    Args:
      keys: [M] int32, M a multiple of ``block``.
      n_bins: virtual workers.
      d: probe depth (salted hash choices per key).
      eps: capacity slack — bin capacity is (1+eps)·m_t/n_bins.
      m0: messages already routed before this call (continuation).
      load0: [n_bins] f32 per-bin loads carried in from a previous call
        (continuation); zeros when omitted.
      interpret: None → auto (compiled on TPU, interpreter elsewhere).
    Returns (assignment [M] int32, final_load [n_bins] f32).
    """
    if d is None:
        d = 4 * n_bins      # same probe ceiling as the sequential oracle
    M = keys.shape[0]
    assert M % block == 0, f"{M} % {block} != 0"
    n_blocks = M // block
    kernel = functools.partial(_porc_kernel, n_bins=n_bins, d=d, block=block,
                               eps=eps, n_blocks=n_blocks)
    m0_arr = jnp.asarray([m0], jnp.float32)
    load0_arr = (jnp.zeros((n_bins,), jnp.float32) if load0 is None
                 else load0.astype(jnp.float32))
    assign, load = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n_bins,), lambda b: (0,)),
            pl.BlockSpec((block,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((n_bins,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M,), jnp.int32),
            jax.ShapeDtypeStruct((n_bins,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_bins,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(m0_arr, load0_arr, keys)
    return assign, load
