"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth the kernels are tested against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes and asserts
equality / allclose).

Oracles
-------
ref_porc_assign   block-synchronous PoRC (the TPU-adapted Alg. 1)
ref_cg_dispatch   capacity-bounded MoE assignment with CG overflow
ref_ssd_scan      Mamba-2 SSD recurrence (exact sequential scan)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_to_bins


# ---------------------------------------------------------------------------
# PoRC, block-synchronous semantics
# ---------------------------------------------------------------------------

def _porc_block(load, keys, cap, n_bins: int, d: int):
    """Assign one block of keys against running loads.

    Rank-sequential, key-vectorized: at rank r, every still-unassigned
    key bids for its r-th salted choice H(key‖r+1); the first
    ``cap − load`` bidders per bin (in block order) are accepted.
    Ranks advance until every key is placed (Alg. 1's unbounded probe),
    with a ceiling of d ranks; the rare leftovers are forced onto their
    rank-d choice.
    """
    B = keys.shape[0]
    assign = jnp.full((B,), -1, jnp.int32)
    unassigned = jnp.ones((B,), bool)

    def cond(carry):
        r, load, assign, unassigned = carry
        return (r < d) & jnp.any(unassigned)

    def rank_step(carry):
        r, load, assign, unassigned = carry
        c = hash_to_bins(keys, (r + 1).astype(jnp.uint32), n_bins)
        onehot = (c[:, None] == jnp.arange(n_bins)[None, :]) & unassigned[:, None]
        pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
        mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
        accept = unassigned & (load[c] + mypos < cap)
        assign = jnp.where(accept, c, assign)
        load = load + jnp.sum(
            onehot.astype(jnp.float32) * accept[:, None].astype(jnp.float32), axis=0)
        return r + 1, load, assign, unassigned & ~accept

    _, load, assign, unassigned = jax.lax.while_loop(
        cond, rank_step, (jnp.int32(0), load, assign, unassigned))
    # forced fallback at probe ceiling: spread leftovers round-robin over
    # the least-loaded bins (the vectorized analogue of Alg. 1's
    # argmin-load fallback; prevents pileup on any single bin).
    order = jnp.argsort(load).astype(jnp.int32)
    leftpos = jnp.cumsum(unassigned.astype(jnp.int32)) - 1
    fallback = order[leftpos % n_bins]
    assign = jnp.where(unassigned, fallback, assign)
    forced = jnp.zeros((n_bins,), jnp.float32).at[fallback].add(
        unassigned.astype(jnp.float32))
    return load + forced, assign


@functools.partial(jax.jit, static_argnames=("n_bins", "d", "block", "eps"))
def ref_porc_assign(keys: jnp.ndarray, n_bins: int, *, d: int | None = None,
                    block: int = 128, eps: float = 0.05,
                    load0: jnp.ndarray | None = None,
                    m0: float = 0.0):
    """Oracle for kernels.porc_assign. keys length must be a multiple of
    ``block``. Returns (assignment [M], final load [n_bins])."""
    if d is None:
        d = 4 * n_bins      # same probe ceiling as the sequential oracle
    M = keys.shape[0]
    assert M % block == 0
    nb = M // block
    kb = keys.reshape(nb, block)
    load = jnp.zeros(n_bins, jnp.float32) if load0 is None else load0

    def blk(load, xs):
        b, keys_blk = xs
        cap = (1.0 + eps) * (m0 + (b + 1.0) * block) / n_bins
        load, assign = _porc_block(load, keys_blk, cap, n_bins, d)
        return load, assign

    load, assign = jax.lax.scan(blk, load,
                                (jnp.arange(nb, dtype=jnp.float32), kb))
    return assign.reshape(-1), load


# ---------------------------------------------------------------------------
# CG MoE dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_experts", "k", "capacity", "block"))
def ref_cg_dispatch(pref: jnp.ndarray, gates: jnp.ndarray, *, n_experts: int,
                    k: int, capacity: int, block: int = 128):
    """Oracle for kernels.cg_dispatch.

    Args:
      pref: [T, D] experts per token sorted by gate desc (D ≥ k gives the
        overflow depth — the PoRC salted-probe sequence analogue).
      gates: [T, D] matching gate scores (softmax probs).
    Returns:
      expert_assign [T, k] int32 (-1 = unplaced), slot [T, k] int32
      (position in the expert's buffer), weights [T, k] f32 (renormalized
      over placed slots), load [E] f32 final per-expert occupancy.
    """
    T, D = pref.shape
    assert T % block == 0

    def blk(load, xs):
        p, g = xs                                            # [B, D]
        B = p.shape[0]
        assign = jnp.full((B, k), -1, jnp.int32)
        slot = jnp.full((B, k), -1, jnp.int32)
        wts = jnp.zeros((B, k), jnp.float32)
        nacc = jnp.zeros((B,), jnp.int32)

        def rank_step(r, carry):
            load, assign, slot, wts, nacc = carry
            c = p[:, r]
            want = nacc < k
            onehot = (c[:, None] == jnp.arange(n_experts)[None, :]) & want[:, None]
            pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
            mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
            myload = load[c] + mypos
            accept = want & (myload < capacity)
            col = (jnp.arange(k)[None, :] == nacc[:, None]) & accept[:, None]
            assign = jnp.where(col, c[:, None], assign)
            slot = jnp.where(col, myload.astype(jnp.int32)[:, None], slot)
            wts = jnp.where(col, g[:, r][:, None], wts)
            load = load + jnp.sum(
                onehot.astype(jnp.float32) * accept[:, None], axis=0)
            return load, assign, slot, wts, nacc + accept.astype(jnp.int32)

        load, assign, slot, wts, nacc = jax.lax.fori_loop(
            0, D, rank_step, (load, assign, slot, wts, nacc))
        denom = jnp.maximum(jnp.sum(wts, -1, keepdims=True), 1e-9)
        return load, (assign, slot, wts / denom)

    load0 = jnp.zeros((n_experts,), jnp.float32)
    load, (assign, slot, wts) = jax.lax.scan(
        blk, load0, (pref.reshape(-1, block, D), gates.reshape(-1, block, D)))
    return (assign.reshape(T, k), slot.reshape(T, k),
            wts.reshape(T, k), load)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ref_ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Exact sequential SSD recurrence (the gold semantics).

    h_t = exp(dt_t·A_h)·h_{t-1} + dt_t·(x_t ⊗ B_t);  y_t = h_t·C_t

    Args:
      x:  [B, L, H, P] inputs per head.
      dt: [B, L, H] positive step sizes.
      A:  [H] negative decay rates.
      Bm: [B, L, G, N] input projections (G groups, H % G == 0).
      Cm: [B, L, G, N] output projections.
    Returns y: [B, L, H, P].
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)                         # [B, L, H, N]
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(h, xs):
        xt, dtt, bt, ct = xs                                  # [B,H,P],[B,H],[B,H,N]x2
        decay = jnp.exp(dtt * A[None, :])[..., None, None]    # [B,H,1,1]
        h = decay * h + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    _, y = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(y, 0, 1).astype(x.dtype)
