"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth the kernels are tested against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes and asserts
equality / allclose).

Oracles
-------
ref_porc_assign   block-synchronous PoRC (the TPU-adapted Alg. 1)
ref_cg_dispatch   capacity-bounded MoE assignment with CG overflow
ref_ssd_scan      Mamba-2 SSD recurrence (exact sequential scan)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_to_bins


# ---------------------------------------------------------------------------
# PoRC, block-synchronous semantics
# ---------------------------------------------------------------------------

def _porc_block(load, keys, cap, n_bins: int, d: int):
    """Assign one block of keys against running loads.

    Rank-sequential, key-vectorized: at rank r, every still-unassigned
    key bids for its r-th salted choice H(key‖r+1); the first
    ``cap − load`` bidders per bin (in block order) are accepted.
    Ranks advance until every key is placed (Alg. 1's unbounded probe),
    with a ceiling of d ranks; the rare leftovers are forced onto their
    rank-d choice.
    """
    B = keys.shape[0]
    assign = jnp.full((B,), -1, jnp.int32)
    unassigned = jnp.ones((B,), bool)

    def cond(carry):
        r, load, assign, unassigned = carry
        return (r < d) & jnp.any(unassigned)

    def rank_step(carry):
        r, load, assign, unassigned = carry
        c = hash_to_bins(keys, (r + 1).astype(jnp.uint32), n_bins)
        onehot = (c[:, None] == jnp.arange(n_bins)[None, :]) & unassigned[:, None]
        pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
        mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
        accept = unassigned & (load[c] + mypos < cap)
        assign = jnp.where(accept, c, assign)
        load = load + jnp.sum(
            onehot.astype(jnp.float32) * accept[:, None].astype(jnp.float32), axis=0)
        return r + 1, load, assign, unassigned & ~accept

    _, load, assign, unassigned = jax.lax.while_loop(
        cond, rank_step, (jnp.int32(0), load, assign, unassigned))
    # forced fallback at probe ceiling: spread leftovers round-robin over
    # the least-loaded bins (the vectorized analogue of Alg. 1's
    # argmin-load fallback; prevents pileup on any single bin).
    order = jnp.argsort(load).astype(jnp.int32)
    leftpos = jnp.cumsum(unassigned.astype(jnp.int32)) - 1
    fallback = order[leftpos % n_bins]
    assign = jnp.where(unassigned, fallback, assign)
    forced = jnp.zeros((n_bins,), jnp.float32).at[fallback].add(
        unassigned.astype(jnp.float32))
    return load + forced, assign


@functools.partial(jax.jit, static_argnames=("n_bins", "d", "block", "eps"))
def ref_porc_assign(keys: jnp.ndarray, n_bins: int, *, d: int | None = None,
                    block: int = 128, eps: float = 0.05,
                    load0: jnp.ndarray | None = None,
                    m0: float = 0.0):
    """Oracle for kernels.porc_assign. keys length must be a multiple of
    ``block``. Returns (assignment [M], final load [n_bins])."""
    if d is None:
        d = 4 * n_bins      # same probe ceiling as the sequential oracle
    M = keys.shape[0]
    assert M % block == 0
    nb = M // block
    kb = keys.reshape(nb, block)
    load = jnp.zeros(n_bins, jnp.float32) if load0 is None else load0

    def blk(load, xs):
        b, keys_blk = xs
        cap = (1.0 + eps) * (m0 + (b + 1.0) * block) / n_bins
        load, assign = _porc_block(load, keys_blk, cap, n_bins, d)
        return load, assign

    load, assign = jax.lax.scan(blk, load,
                                (jnp.arange(nb, dtype=jnp.float32), kb))
    return assign.reshape(-1), load


# ---------------------------------------------------------------------------
# PoRC state carried across blocks / calls (the block-parallel runtime)
# ---------------------------------------------------------------------------

class PorcState(NamedTuple):
    """Routing state threaded across blocks, slots, and batches.

    ``load`` is the (eventually-consistent) per-bin message count and
    ``routed`` the global message clock m_t that drives the capacity
    (1+eps)·m_t/n — together they are everything Alg. 1 remembers.
    """
    load: jnp.ndarray     # [n_bins] f32
    routed: jnp.ndarray   # []       f32


def porc_state_init(n_bins: int) -> PorcState:
    return PorcState(load=jnp.zeros(n_bins, jnp.float32),
                     routed=jnp.zeros((), jnp.float32))


def block_spans(m: int, block: int) -> list[tuple[int, int, int]]:
    """(start, length, engine_block) spans covering an m-message stream.

    Full blocks come as one span; the trailing remainder is decomposed
    into powers of two. The jitted block engines specialize on
    (length, block), so this bounds the distinct remainder programs at
    O(log block) instead of one per possible remainder length — the
    serving path sees arbitrary batch sizes every call.
    """
    spans = []
    nb = m // block
    off = nb * block
    if nb:
        spans.append((0, off, block))
    rem = m - off
    while rem:
        p = 1 << (rem.bit_length() - 1)
        spans.append((off, p, p))
        off += p
        rem -= p
    return spans


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "eps", "chunk"))
def ref_porc_snapshot(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                      eps: float = 0.05, chunk: int = 8,
                      load0: jnp.ndarray | None = None, m0: float = 0.0):
    """Snapshot-probing PoRC: the block-parallel *fast path*.

    Every message in a block independently walks its salted-probe chain
    H(j‖1), H(j‖2), … against the load snapshot taken at the block
    boundary and stops at the first bin below (1+eps)·m_t/n (m_t at
    block end); loads update once per block. This is the paper's §V-C
    eventual consistency — the same semantics as multiple sources
    routing with local load views — so a bin can overshoot the capacity
    by at most the number of duplicates of its keys inside one block.

    Unlike the rank-sequential ``ref_porc_assign`` (which resolves
    in-block contention rank by rank and therefore serializes ~max-key-
    multiplicity steps per block), every probe here is a vectorized
    gather, which is what makes the block path fast on CPU/TPU.

    Probe budget: at block=1 the full 4·n_bins salted chain of Alg. 1
    runs (lazily, in chunks of ``chunk`` salts) so the result is
    bit-identical to the sequential oracle — the snapshot *is* the true
    load. At block>1 each message gets a fixed budget of ``chunk``
    probes per snapshot (hoisted out of the block scan entirely, since
    they are load-independent); either way, exhausting the budget falls
    back to the least-loaded snapshot bin, Alg. 1's fallback. A fixed
    budget is the right trade at block>1 because a fresh snapshot
    resolves ~everything within a few probes — paying a data-dependent
    while-loop per block costs more than the rare deep chain saves.

    Returns (assignment [M] int32, final load [n_bins] f32).
    """
    M = keys.shape[0]
    assert M % block == 0, f"{M} % {block} != 0"
    nb = M // block
    kb = keys.reshape(nb, block)
    max_probes = 4 * n_bins
    load = jnp.zeros(n_bins, jnp.float32) if load0 is None else load0
    # the first chunk of candidates is load-independent → hoist the
    # hashing for the whole stream out of the per-block scan
    salts0 = jnp.arange(1, chunk + 1, dtype=jnp.uint32)
    cand0 = hash_to_bins(kb[:, :, None], salts0[None, None, :], n_bins)

    def resolve(load, cap, cand, salts, assign):
        ok = (load[cand] < cap) & (salts <= max_probes)[None, :]
        first = jnp.argmax(ok, axis=1)
        pick = jnp.take_along_axis(cand, first[:, None], 1)[:, 0]
        hit = (assign < 0) & jnp.any(ok, axis=1)
        return jnp.where(hit, pick, assign)

    def blk(load, xs):
        b, kblk, cblk = xs
        cap = (1.0 + eps) * (m0 + (b + 1.0) * block) / n_bins
        assign = resolve(load, cap, cblk, salts0,
                         jnp.full((block,), -1, jnp.int32))

        if block == 1:
            # exactness: continue the salted chain to the oracle ceiling
            def cond(c):
                salt0, assign = c
                return (salt0 <= max_probes) & jnp.any(assign < 0)

            def probe_chunk(c):
                salt0, assign = c
                salts = salt0 + jnp.arange(chunk, dtype=jnp.uint32)
                cand = hash_to_bins(kblk[:, None], salts[None, :], n_bins)
                return salt0 + chunk, resolve(load, cap, cand, salts, assign)

            _, assign = jax.lax.while_loop(
                cond, probe_chunk, (jnp.uint32(1 + chunk), assign))

        # probe budget exhausted: least-loaded snapshot bin (Alg. 1)
        assign = jnp.where(assign < 0, jnp.argmin(load).astype(jnp.int32),
                           assign)
        return load.at[assign].add(1.0), assign

    load, assign = jax.lax.scan(blk, load,
                                (jnp.arange(nb, dtype=jnp.float32), kb, cand0))
    return assign.reshape(-1), load


def route_in_spans(keys: jnp.ndarray, block: int, carry, step):
    """Drive a jitted block engine over ``block_spans`` of a stream.

    ``step(sub_keys, engine_block, carry) -> (assignment, carry)`` is
    called per span with the threaded carry (load state). Returns the
    concatenated assignment and the final carry.
    """
    parts = []
    for start, length, blk in block_spans(keys.shape[0], block):
        a, carry = step(keys[start: start + length], blk, carry)
        parts.append(a)
    if not parts:
        return jnp.zeros((0,), jnp.int32), carry
    return (parts[0] if len(parts) == 1 else jnp.concatenate(parts)), carry


def ref_porc_route(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                   eps: float = 0.05, state: PorcState | None = None,
                   engine: str = "snapshot"):
    """Route an arbitrary-length key stream in blocks of ``block``.

    ``engine="snapshot"`` (the fast path) probes block-boundary load
    snapshots via ``ref_porc_snapshot``; ``engine="strict"`` uses the
    rank-sequential ``ref_porc_assign``, which never exceeds the
    (1+eps) cap but serializes in-block contention (slower — use it
    when the ε guarantee must hold exactly, e.g. tiny per-bin loads).
    Either way a trailing partial block is routed as power-of-two
    sub-blocks (caps at each sub-block end, bounded recompilation —
    see ``block_spans``), so no padding keys ever pollute the load
    state. With ``block=1`` both engines are bit-identical to the
    sequential oracle ``partitioners.power_of_random_choices``.

    Returns (assignment [M] int32, new PorcState).
    """
    if state is None:
        state = porc_state_init(n_bins)
    eng = {"snapshot": ref_porc_snapshot,
           "strict": ref_porc_assign}[engine]

    def step(sub, blk, carry):
        load, routed = carry
        a, load = eng(sub, n_bins, block=blk, eps=eps, load0=load, m0=routed)
        return a, (load, routed + sub.shape[0])

    assign, (load, routed) = route_in_spans(
        keys, block, (state.load, state.routed), step)
    return assign, PorcState(load=load, routed=routed)


# ---------------------------------------------------------------------------
# CG MoE dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_experts", "k", "capacity", "block"))
def ref_cg_dispatch(pref: jnp.ndarray, gates: jnp.ndarray, *, n_experts: int,
                    k: int, capacity: int, block: int = 128):
    """Oracle for kernels.cg_dispatch.

    Args:
      pref: [T, D] experts per token sorted by gate desc (D ≥ k gives the
        overflow depth — the PoRC salted-probe sequence analogue).
      gates: [T, D] matching gate scores (softmax probs).
    Returns:
      expert_assign [T, k] int32 (-1 = unplaced), slot [T, k] int32
      (position in the expert's buffer), weights [T, k] f32 (renormalized
      over placed slots), load [E] f32 final per-expert occupancy.
    """
    T, D = pref.shape
    assert T % block == 0

    def blk(load, xs):
        p, g = xs                                            # [B, D]
        B = p.shape[0]
        assign = jnp.full((B, k), -1, jnp.int32)
        slot = jnp.full((B, k), -1, jnp.int32)
        wts = jnp.zeros((B, k), jnp.float32)
        nacc = jnp.zeros((B,), jnp.int32)

        def rank_step(r, carry):
            load, assign, slot, wts, nacc = carry
            c = p[:, r]
            want = nacc < k
            onehot = (c[:, None] == jnp.arange(n_experts)[None, :]) & want[:, None]
            pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
            mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
            myload = load[c] + mypos
            accept = want & (myload < capacity)
            col = (jnp.arange(k)[None, :] == nacc[:, None]) & accept[:, None]
            assign = jnp.where(col, c[:, None], assign)
            slot = jnp.where(col, myload.astype(jnp.int32)[:, None], slot)
            wts = jnp.where(col, g[:, r][:, None], wts)
            load = load + jnp.sum(
                onehot.astype(jnp.float32) * accept[:, None], axis=0)
            return load, assign, slot, wts, nacc + accept.astype(jnp.int32)

        load, assign, slot, wts, nacc = jax.lax.fori_loop(
            0, D, rank_step, (load, assign, slot, wts, nacc))
        denom = jnp.maximum(jnp.sum(wts, -1, keepdims=True), 1e-9)
        return load, (assign, slot, wts / denom)

    load0 = jnp.zeros((n_experts,), jnp.float32)
    load, (assign, slot, wts) = jax.lax.scan(
        blk, load0, (pref.reshape(-1, block, D), gates.reshape(-1, block, D)))
    return (assign.reshape(T, k), slot.reshape(T, k),
            wts.reshape(T, k), load)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ref_ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Exact sequential SSD recurrence (the gold semantics).

    h_t = exp(dt_t·A_h)·h_{t-1} + dt_t·(x_t ⊗ B_t);  y_t = h_t·C_t

    Args:
      x:  [B, L, H, P] inputs per head.
      dt: [B, L, H] positive step sizes.
      A:  [H] negative decay rates.
      Bm: [B, L, G, N] input projections (G groups, H % G == 0).
      Cm: [B, L, G, N] output projections.
    Returns y: [B, L, H, P].
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)                         # [B, L, H, N]
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(h, xs):
        xt, dtt, bt, ct = xs                                  # [B,H,P],[B,H],[B,H,N]x2
        decay = jnp.exp(dtt * A[None, :])[..., None, None]    # [B,H,1,1]
        h = decay * h + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    _, y = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(y, 0, 1).astype(x.dtype)
