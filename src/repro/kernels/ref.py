"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth the kernels are tested against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes and asserts
equality / allclose).

Oracles
-------
ref_porc_assign   block-synchronous PoRC (the TPU-adapted Alg. 1)
ref_cg_dispatch   capacity-bounded MoE assignment with CG overflow
ref_ssd_scan      Mamba-2 SSD recurrence (exact sequential scan)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_to_bins

# The block-engine inner math lives in kernels/blocks.py so the jnp
# reference engines here and the Pallas engines in porc_snapshot.py
# consume literally the same implementation. Re-exported under the
# historical names — every external import site says
# ``from repro.kernels.ref import X`` and keeps working.
from .blocks import (  # noqa: F401  (re-exports)
    HHPolicy,
    SKETCH_SALT0 as _SKETCH_SALT0,
    hh_budgets as _hh_budgets,
    hh_chunk as _hh_chunk,
    hh_sketch_init,
    hh_sketch_query,
    hh_sketch_update,
    neutral_hh_policy,
    probe_salts,
    sketch_cols as _sketch_cols,
    snapshot_block as _snapshot_block,
    snapshot_block_hh as _snapshot_block_hh,
    snapshot_cap,
    snapshot_resolve as _snapshot_resolve,
    view_cap,
)


# ---------------------------------------------------------------------------
# PoRC, block-synchronous semantics
# ---------------------------------------------------------------------------

def _porc_block(load, keys, cap, n_bins: int, d: int):
    """Assign one block of keys against running loads.

    Rank-sequential, key-vectorized: at rank r, every still-unassigned
    key bids for its r-th salted choice H(key‖r+1); the first
    ``cap − load`` bidders per bin (in block order) are accepted.
    Ranks advance until every key is placed (Alg. 1's unbounded probe),
    with a ceiling of d ranks; the rare leftovers are forced onto their
    rank-d choice.
    """
    B = keys.shape[0]
    assign = jnp.full((B,), -1, jnp.int32)
    unassigned = jnp.ones((B,), bool)

    def cond(carry):
        r, load, assign, unassigned = carry
        return (r < d) & jnp.any(unassigned)

    def rank_step(carry):
        r, load, assign, unassigned = carry
        c = hash_to_bins(keys, (r + 1).astype(jnp.uint32), n_bins)
        onehot = (c[:, None] == jnp.arange(n_bins)[None, :]) & unassigned[:, None]
        pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
        mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
        accept = unassigned & (load[c] + mypos < cap)
        assign = jnp.where(accept, c, assign)
        load = load + jnp.sum(
            onehot.astype(jnp.float32) * accept[:, None].astype(jnp.float32), axis=0)
        return r + 1, load, assign, unassigned & ~accept

    _, load, assign, unassigned = jax.lax.while_loop(
        cond, rank_step, (jnp.int32(0), load, assign, unassigned))
    # forced fallback at probe ceiling: spread leftovers round-robin over
    # the least-loaded bins (the vectorized analogue of Alg. 1's
    # argmin-load fallback; prevents pileup on any single bin).
    order = jnp.argsort(load).astype(jnp.int32)
    leftpos = jnp.cumsum(unassigned.astype(jnp.int32)) - 1
    fallback = order[leftpos % n_bins]
    assign = jnp.where(unassigned, fallback, assign)
    forced = jnp.zeros((n_bins,), jnp.float32).at[fallback].add(
        unassigned.astype(jnp.float32))
    return load + forced, assign


@functools.partial(jax.jit, static_argnames=("n_bins", "d", "block", "eps"))
def ref_porc_assign(keys: jnp.ndarray, n_bins: int, *, d: int | None = None,
                    block: int = 128, eps: float = 0.05,
                    load0: jnp.ndarray | None = None,
                    m0: float = 0.0):
    """Oracle for kernels.porc_assign. keys length must be a multiple of
    ``block``. Returns (assignment [M], final load [n_bins])."""
    if d is None:
        d = 4 * n_bins      # same probe ceiling as the sequential oracle
    M = keys.shape[0]
    assert M % block == 0
    nb = M // block
    kb = keys.reshape(nb, block)
    load = jnp.zeros(n_bins, jnp.float32) if load0 is None else load0

    def blk(load, xs):
        b, keys_blk = xs
        cap = snapshot_cap(eps, n_bins, m0, b, block)
        load, assign = _porc_block(load, keys_blk, cap, n_bins, d)
        return load, assign

    load, assign = jax.lax.scan(blk, load,
                                (jnp.arange(nb, dtype=jnp.float32), kb))
    return assign.reshape(-1), load


# ---------------------------------------------------------------------------
# PoRC state carried across blocks / calls (the block-parallel runtime)
# ---------------------------------------------------------------------------

class PorcState(NamedTuple):
    """Routing state threaded across blocks, slots, and batches.

    ``load`` is the (eventually-consistent) per-bin message count and
    ``routed`` the global message clock m_t that drives the capacity
    (1+eps)·m_t/n — together they are everything Alg. 1 remembers.
    ``sketch`` is the count-min heavy-hitter sketch that drives the
    per-key probe depths when a :class:`HHPolicy` is active (``None``
    otherwise — the default engine never materializes it).

    State-carry contract: every field continues across calls — splitting
    a stream over multiple ``ref_porc_route`` calls with the carried
    state is bit-identical to one call (block boundaries realign per
    call, the only alignment caveat). Nothing here resets at slot
    boundaries; the CG simulator carries the state through
    ``CGState.vw_load``/``t_offset``/``sketch`` instead.
    """
    load: jnp.ndarray     # [n_bins] f32
    routed: jnp.ndarray   # []       f32
    sketch: jnp.ndarray | None = None   # [depth, width] f32 count-min
                          # counts (only when an HHPolicy is active)


def porc_state_init(n_bins: int,
                    policy: "HHPolicy | None" = None) -> PorcState:
    return PorcState(load=jnp.zeros(n_bins, jnp.float32),
                     routed=jnp.zeros((), jnp.float32),
                     sketch=None if policy is None else hh_sketch_init(policy))


def block_spans(m: int, block: int) -> list[tuple[int, int, int]]:
    """(start, length, engine_block) spans covering an m-message stream.

    Full blocks come as one span; the trailing remainder is decomposed
    into powers of two. The jitted block engines specialize on
    (length, block), so this bounds the distinct remainder programs at
    O(log block) instead of one per possible remainder length — the
    serving path sees arbitrary batch sizes every call.
    """
    spans = []
    nb = m // block
    off = nb * block
    if nb:
        spans.append((0, off, block))
    rem = m - off
    while rem:
        p = 1 << (rem.bit_length() - 1)
        spans.append((off, p, p))
        off += p
        rem -= p
    return spans


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "eps", "chunk"))
def ref_porc_snapshot(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                      eps: float = 0.05, chunk: int = 8,
                      load0: jnp.ndarray | None = None, m0: float = 0.0):
    """Snapshot-probing PoRC: the block-parallel *fast path*.

    Every message in a block independently walks its salted-probe chain
    H(j‖1), H(j‖2), … against the load snapshot taken at the block
    boundary and stops at the first bin below (1+eps)·m_t/n (m_t at
    block end); loads update once per block. This is the paper's §V-C
    eventual consistency — the same semantics as multiple sources
    routing with local load views — so a bin can overshoot the capacity
    by at most the number of duplicates of its keys inside one block.

    Unlike the rank-sequential ``ref_porc_assign`` (which resolves
    in-block contention rank by rank and therefore serializes ~max-key-
    multiplicity steps per block), every probe here is a vectorized
    gather, which is what makes the block path fast on CPU/TPU.

    Probe budget: at block=1 the full 4·n_bins salted chain of Alg. 1
    runs (lazily, in chunks of ``chunk`` salts) so the result is
    bit-identical to the sequential oracle — the snapshot *is* the true
    load. At block>1 each message gets a fixed budget of ``chunk``
    probes per snapshot (hoisted out of the block scan entirely, since
    they are load-independent); either way, exhausting the budget falls
    back to the least-loaded snapshot bin, Alg. 1's fallback. A fixed
    budget is the right trade at block>1 because a fresh snapshot
    resolves ~everything within a few probes — paying a data-dependent
    while-loop per block costs more than the rare deep chain saves.

    Returns (assignment [M] int32, final load [n_bins] f32).
    """
    M = keys.shape[0]
    assert M % block == 0, f"{M} % {block} != 0"
    nb = M // block
    kb = keys.reshape(nb, block)
    load = jnp.zeros(n_bins, jnp.float32) if load0 is None else load0
    # the first chunk of candidates is load-independent → hoist the
    # hashing for the whole stream out of the per-block scan
    salts0 = probe_salts(chunk)
    cand0 = hash_to_bins(kb[:, :, None], salts0[None, None, :], n_bins)

    def blk(load, xs):
        b, kblk, cblk = xs
        cap = snapshot_cap(eps, n_bins, m0, b, block)
        assign = _snapshot_block(load, cap, kblk, cblk, n_bins, block, chunk)
        return load.at[assign].add(1.0), assign

    load, assign = jax.lax.scan(blk, load,
                                (jnp.arange(nb, dtype=jnp.float32), kb, cand0))
    return assign.reshape(-1), load


def route_in_spans(keys: jnp.ndarray, block: int, carry, step):
    """Drive a jitted block engine over ``block_spans`` of a stream.

    ``step(sub_keys, engine_block, carry) -> (assignment, carry)`` is
    called per span with the threaded carry (load state). Returns the
    concatenated assignment and the final carry.
    """
    parts = []
    for start, length, blk in block_spans(keys.shape[0], block):
        a, carry = step(keys[start: start + length], blk, carry)
        parts.append(a)
    if not parts:
        return jnp.zeros((0,), jnp.int32), carry
    return (parts[0] if len(parts) == 1 else jnp.concatenate(parts)), carry


def ref_porc_route(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                   eps: float = 0.05, state: PorcState | None = None,
                   engine: str = "snapshot",
                   policy: HHPolicy | None = None):
    """Route an arbitrary-length key stream in blocks of ``block``.

    ``engine="snapshot"`` (the fast path) probes block-boundary load
    snapshots via ``ref_porc_snapshot``; ``engine="pallas"`` runs the
    same semantics as the Pallas kernel
    (``porc_snapshot.porc_snapshot`` — bit-identical, load in VMEM
    scratch, compiled on TPU / interpreted elsewhere);
    ``engine="strict"`` uses the rank-sequential ``ref_porc_assign``,
    which never exceeds the (1+eps) cap but serializes in-block
    contention (slower — use it when the ε guarantee must hold exactly,
    e.g. tiny per-bin loads). The user-facing ``"ref"``/``"auto"``
    spellings resolve to these via ``kernels.backend.resolve_engine``.
    Either way a trailing partial block is routed as power-of-two
    sub-blocks (caps at each sub-block end, bounded recompilation —
    see ``block_spans``), so no padding keys ever pollute the load
    state. With ``block=1`` both engines are bit-identical to the
    sequential oracle ``partitioners.power_of_random_choices``.

    ``policy`` (snapshot engine only) turns on heavy-hitter-aware probe
    depths — D/W-Choices, see :class:`HHPolicy` — with the count-min
    sketch carried in ``state.sketch``; it routes through the
    multi-source engine at S=1 (bit-identical framing, CI-gated for the
    policy-free case). With a policy, ``block=1`` is *not* the
    sequential oracle: the probe budget is policy-defined, not Alg. 1's
    4·n chain.

    State-carry contract: ``state`` (load, clock, sketch) continues
    across calls — split-call == one-call with aligned block
    boundaries; nothing resets here.

    Returns (assignment [M] int32, new PorcState).
    """
    if state is None:
        state = porc_state_init(n_bins, policy)
    if policy is not None:
        if engine not in ("snapshot", "pallas"):
            raise ValueError("HHPolicy requires the snapshot engine")
        skb = state.sketch if state.sketch is not None \
            else hh_sketch_init(policy)
        ms = MultiSourcePorcState(
            base=state.load,
            delta=jnp.zeros((1, n_bins), jnp.float32),
            routed=state.routed,
            ticks=jnp.zeros((), jnp.int32),
            sketch_base=skb,
            sketch_delta=jnp.zeros((1,) + skb.shape, jnp.float32))
        assign, ms = ref_porc_multisource(
            keys, n_bins, 1, sync_every=1, block=block, eps=eps,
            state=ms, engine=engine, policy=policy)
        return assign, PorcState(
            load=ms.base + ms.delta.sum(0), routed=ms.routed,
            sketch=ms.sketch_base + ms.sketch_delta.sum(0))
    if engine == "pallas":
        from .porc_snapshot import porc_snapshot as eng  # deferred: pallas
    else:
        eng = {"snapshot": ref_porc_snapshot,
               "strict": ref_porc_assign}[engine]

    def step(sub, blk, carry):
        load, routed = carry
        a, load = eng(sub, n_bins, block=blk, eps=eps, load0=load, m0=routed)
        return a, (load, routed + sub.shape[0])

    assign, (load, routed) = route_in_spans(
        keys, block, (state.load, state.routed), step)
    return assign, PorcState(load=load, routed=routed)


# ---------------------------------------------------------------------------
# Multi-source PoRC — §V-C distributed sources with local load views
# ---------------------------------------------------------------------------

class MultiSourcePorcState(NamedTuple):
    """Routing state of S sources sharing one bin population (§V-C).

    Each source routes against its *local* load view ``base + delta[s]``:
    the last synchronized global load plus its own unpublished counts.
    ``delta`` is merged into ``base`` every ``sync_every`` blocks — the
    paper's piggybacked load synchronization — so a source's view is
    stale by at most one sync period of the other sources' traffic.
    ``ticks`` carries the sync phase (blocks routed since the last
    merge) across calls, so a stream fed in batches shorter than one
    sync period still merges on schedule instead of never.

    When an :class:`HHPolicy` is active the count-min sketch shards the
    same way: ``sketch_base`` is the merged sketch and
    ``sketch_delta[s]`` source s's unpublished counts — a source
    classifies keys against its *local* sketch view ``sketch_base +
    sketch_delta[s]`` and the deltas merge (by addition — the sketch is
    linear) on the same schedule as the load deltas. Both stay ``None``
    without a policy.

    State-carry contract: every field continues across
    ``ref_porc_multisource`` calls (split-call == one-call, CI-gated);
    ``multisource_merge`` — and the sub-S ragged tail, which publishes
    immediately — fold the deltas into the bases and reset ``ticks``,
    which is what a monitoring-slot boundary does.
    """
    base: jnp.ndarray     # [n_bins]    f32 merged (synchronized) load
    delta: jnp.ndarray    # [S, n_bins] f32 per-source unpublished counts
    routed: jnp.ndarray   # []          f32 global message clock m_t
    ticks: jnp.ndarray    # []          i32 blocks since the last merge
    sketch_base: jnp.ndarray | None = None    # [depth, width] f32 merged
                          # count-min counts (HHPolicy only)
    sketch_delta: jnp.ndarray | None = None   # [S, depth, width] f32
                          # per-source unpublished sketch counts


def multisource_state_init(n_bins: int, n_sources: int,
                           policy: "HHPolicy | None" = None,
                           ) -> MultiSourcePorcState:
    return MultiSourcePorcState(
        base=jnp.zeros(n_bins, jnp.float32),
        delta=jnp.zeros((n_sources, n_bins), jnp.float32),
        routed=jnp.zeros((), jnp.float32),
        ticks=jnp.zeros((), jnp.int32),
        sketch_base=None if policy is None else hh_sketch_init(policy),
        sketch_delta=None if policy is None else jnp.zeros(
            (n_sources, policy.depth, policy.width), jnp.float32))


@functools.partial(jax.jit, static_argnames=(
    "n_bins", "n_sources", "sync_every", "block", "eps", "chunk", "engine",
    "policy"))
def _porc_multisource_scan(keys: jnp.ndarray, n_bins: int, n_sources: int,
                           sync_every: int, block: int, eps: float,
                           chunk: int, engine: str, base0, delta0, ticks0,
                           skb0=None, skd0=None,
                           policy: HHPolicy | None = None):
    """Core multi-source scan over full per-source blocks.

    ``keys`` is the round-robin-interleaved global stream (message i
    belongs to source i % S); its length must be a multiple of S·block.
    Per scan step every source routes one block of its substream against
    ``base + delta[s]`` (``_snapshot_block`` or the rank-sequential
    ``_porc_block``, vmapped over sources); every ``sync_every`` steps
    the deltas merge into the base.

    With a ``policy`` (snapshot engine only) each source additionally
    classifies its block against its local sketch view at the block
    boundary, routes with per-key probe budgets
    (``_snapshot_block_hh``), and folds the block into its sketch delta
    afterwards — so the heavy/tail decision is one block stale, the
    same staleness license as the load snapshot itself. ``policy=None``
    traces to exactly the sketch-free engine (bit-identical).
    """
    S = n_sources
    M = keys.shape[0]
    assert M % (S * block) == 0, f"{M} % {S}*{block} != 0"
    nb = M // (S * block)
    # [nb, S, block]: element [b, s, k] = keys[(b·block + k)·S + s],
    # source s's k-th message of its b-th block
    kb = keys.reshape(nb, block, S).transpose(0, 2, 1)
    if engine == "snapshot":
        chunk_eff = (chunk if policy is None
                     else _hh_chunk(policy, chunk, n_bins))
        salts0 = probe_salts(chunk_eff)
        if policy is None:
            cand0 = hash_to_bins(kb[..., None], salts0, n_bins)
            xs_extra = (cand0,)             # [nb, S, block, C] hoisted
        else:
            # the policy chain can be n_bins deep — hash per block inside
            # the scan instead of hoisting [nb, S, block, n_bins] for the
            # whole stream
            xs_extra = ()
        route_block = jax.vmap(
            lambda view, cap, kblk, cblk: _snapshot_block(
                view, cap, kblk, cblk, n_bins, block, chunk),
            in_axes=(0, 0, 0, 0))
    else:        # "strict": in-block contention resolved rank by rank
        assert policy is None, "HHPolicy requires the snapshot engine"
        xs_extra = ()
        route_block = jax.vmap(
            lambda view, cap, kblk: _porc_block(
                view, kblk, cap, n_bins, 4 * n_bins)[1],
            in_axes=(0, 0, 0))

    def blk(carry, xs):
        base, delta, skb, skd = carry
        b, kblk, *extra = xs
        # Per-source capacity from the mass of its *local view* (merged
        # base + own delta) — not the global clock. A cap the source
        # cannot verify against its view would let all S sources fill a
        # hot bin to the global cap independently (S× overshoot at cold
        # start); the local-mass cap keeps the strict per-source
        # invariant load_view ≤ (1+eps)·mass_view/n, whose sum
        # telescopes to the global (1+eps)·m/n envelope — exactly why
        # the paper's independent-sources argument works. The arriving
        # block enters the mass as block/S so the *aggregate* lookahead
        # across sources is one block, matching the single-source m_t
        # (at S=1 this reduces bit-exactly to ``ref_porc_snapshot``'s
        # capacity); a full +block per source would hand the S sources
        # S·(1+eps)·block/n of joint slack on a shared hot bin.
        mass = base.sum() + delta.sum(1)                  # [S] local view
        cap = view_cap(eps, n_bins, mass, block / S)
        views = base[None, :] + delta                     # [S, n_bins]
        if policy is None:
            assign = route_block(views, cap, kblk, *extra)   # [S, block]
        else:
            # heavy/tail classification against the block-boundary local
            # sketch view, per-key budgets from the probe-depth schedule
            cand = hash_to_bins(kblk[..., None], salts0, n_bins)
            est = jax.vmap(lambda d, k: hh_sketch_query(policy, skb + d, k))(
                skd, kblk)                                # [S, block]
            bud = _hh_budgets(policy, n_bins, eps, est, mass[:, None])
            assign = jax.vmap(
                lambda view, c, kk, cblk, bd: _snapshot_block_hh(
                    view, c, kk, cblk, bd, n_bins,
                    policy.rotate_duplicates, policy.spread_fallback))(
                views, cap, kblk, cand, bud)
            skd = jax.vmap(lambda d, k: hh_sketch_update(policy, d, k))(
                skd, kblk)
        delta = jax.vmap(lambda d, a: d.at[a].add(1.0))(delta, assign)
        # piggyback merge — phase continues from ticks0 across calls
        sync = ((ticks0 + b + 1) % sync_every) == 0
        base = jnp.where(sync, base + delta.sum(0), base)
        delta = jnp.where(sync, jnp.zeros_like(delta), delta)
        if policy is not None:
            skb = jnp.where(sync, skb + skd.sum(0), skb)
            skd = jnp.where(sync, jnp.zeros_like(skd), skd)
        return (base, delta, skb, skd), assign

    (base, delta, skb, skd), assign = jax.lax.scan(
        blk, (base0, delta0, skb0, skd0),
        (jnp.arange(nb, dtype=jnp.int32), kb, *xs_extra))
    # invert the round-robin interleave back to global message order
    return (assign.transpose(0, 2, 1).reshape(-1), base, delta,
            (ticks0 + nb) % sync_every, skb, skd)


@functools.partial(jax.jit, static_argnames=("n_bins", "n_sources", "eps",
                                             "chunk", "policy"))
def _porc_multisource_tail(keys_pad: jnp.ndarray, n_bins: int, n_sources: int,
                           eps: float, chunk: int, base0, delta0, n_tail,
                           skb0=None, skd0=None,
                           policy: HHPolicy | None = None):
    """Ragged tail: the final r < S messages, one to each of sources
    0..r-1. ``keys_pad`` is padded to [S]; sources ≥ ``n_tail`` route a
    phantom key whose assignment is discarded and whose delta update is
    masked out, so one compiled program covers every r. The residue
    publishes immediately (merged base, zero deltas — and likewise the
    sketch, when a policy is active): it is less than one block, so it
    cannot advance the block-granular sync phase, and leaving it
    unpublished would let a stream fed in sub-S batches accumulate lane
    deltas that never merge — breaking the documented one-sync-period
    staleness bound.
    """
    S = n_sources
    active = (jnp.arange(S) < n_tail)
    chunk_eff = chunk if policy is None else _hh_chunk(policy, chunk, n_bins)
    cand0 = hash_to_bins(keys_pad[:, None, None], probe_salts(chunk_eff),
                         n_bins)
    mass = base0.sum() + delta0.sum(1)
    cap = view_cap(eps, n_bins, mass, 1.0 / S)
    if policy is None:
        assign = jax.vmap(
            lambda view, kblk, cblk, c: _snapshot_block(
                view, c, kblk, cblk, n_bins, 1, chunk))(
            base0[None, :] + delta0, keys_pad[:, None], cand0, cap)[:, 0]
        skb, skd = skb0, skd0
    else:
        est = jax.vmap(
            lambda d, k: hh_sketch_query(policy, skb0 + d, k))(
            skd0, keys_pad[:, None])                       # [S, 1]
        bud = _hh_budgets(policy, n_bins, eps, est, mass[:, None])
        assign = jax.vmap(
            lambda view, kk, cblk, c, bd: _snapshot_block_hh(
                view, c, kk, cblk, bd, n_bins,
                policy.rotate_duplicates, policy.spread_fallback))(
            base0[None, :] + delta0, keys_pad[:, None], cand0, cap,
            bud)[:, 0]
        skd = jax.vmap(
            lambda d, k, m: hh_sketch_update(policy, d, k, weights=m))(
            skd0, keys_pad[:, None], active.astype(jnp.float32)[:, None])
        skb = skb0 + skd.sum(0)
        skd = jnp.zeros_like(skd)
    delta = jax.vmap(lambda d, a, m: d.at[a].add(m))(
        delta0, assign, active.astype(jnp.float32))
    return assign, base0 + delta.sum(0), jnp.zeros_like(delta), skb, skd


def ref_porc_multisource(keys: jnp.ndarray, n_bins: int, n_sources: int, *,
                         sync_every: int = 1, block: int = 128,
                         eps: float = 0.05, chunk: int = 8,
                         state: MultiSourcePorcState | None = None,
                         engine: str = "snapshot",
                         policy: HHPolicy | None = None):
    """Multi-source block-parallel PoRC (§V-C distributed sources).

    The stream splits round-robin across ``n_sources`` sources (message
    i → source i % S, the paper's SG assignment of messages to sources);
    each source routes blocks of ``block`` messages against its local
    view ``base + own delta`` and the deltas merge into the shared base
    every ``sync_every`` blocks (piggybacked synchronization). Staleness
    is therefore bounded by one sync period: a source never misses more
    than the other S−1 sources' ``sync_every·block`` most recent
    messages.

    ``engine`` picks the per-block router, same choice as
    ``ref_porc_route``: ``"snapshot"`` (the fast path — each block
    probes a frozen local view), ``"pallas"`` (the same semantics as
    the Pallas kernel ``porc_snapshot.porc_multisource_scan`` —
    bit-identical, delta/sketch lanes in VMEM scratch; the ragged tail
    and span driver below stay shared) or ``"strict"`` (rank-sequential
    ``_porc_block`` — in-block contention resolved against the cap,
    slower but exact inside a block; use it when per-bin loads are a
    handful of messages, e.g. Fig 11's 100-source / 1000-VW point,
    where one block of snapshot staleness would dominate the ε
    mechanism).

    With ``n_sources=1, sync_every=1`` the local view *is* the running
    load, so the result is bit-identical to ``ref_porc_route`` with the
    same engine (and at ``block=1`` to the sequential oracle). Arbitrary
    stream lengths are handled like ``ref_porc_route``: the per-source
    remainder routes as power-of-two sub-blocks (``block_spans``), and a
    final sub-S ragged tail routes one message per source with the
    others masked (and publishes immediately — see
    ``_porc_multisource_tail``). The sync phase carries across spans and
    calls via ``state.ticks`` (block-granular, so a stream fed in short
    batches still merges every ``sync_every`` blocks); block boundaries
    themselves realign per call, the same alignment caveat as
    ``ref_porc_route``.

    ``policy`` (snapshot engine only) turns on heavy-hitter-aware probe
    depths (D/W-Choices): each source classifies keys against its local
    count-min sketch view and probes with per-key budgets; the sketch
    shards and delta-merges exactly like the load (see
    :class:`HHPolicy`). ``policy=None`` — the default — is bit-identical
    to the policy-free engine.

    Returns (assignment [M] int32 in original stream order,
    new MultiSourcePorcState).
    """
    S = n_sources
    if engine not in ("snapshot", "strict", "pallas"):
        raise ValueError(f"unknown engine {engine!r}")
    if policy is not None and engine not in ("snapshot", "pallas"):
        raise ValueError("HHPolicy requires the snapshot engine")
    if state is None:
        state = multisource_state_init(n_bins, S, policy)
    base, delta, routed, ticks, skb, skd = state
    if policy is not None and skb is None:
        # state predates the policy: start the sketch cold
        skb = hh_sketch_init(policy)
        skd = jnp.zeros((S, policy.depth, policy.width), jnp.float32)
    if policy is None:
        skb = skd = None                 # sketch is carried only with it
    per = keys.shape[0] // S             # full per-source span length
    r = keys.shape[0] - per * S
    parts = []
    off = 0
    for _, length, blk in block_spans(per, block):
        span = keys[off: off + length * S]
        if engine == "pallas":
            from .porc_snapshot import porc_multisource_scan  # deferred
            a, base, delta, ticks, skb, skd = porc_multisource_scan(
                span, n_bins, S, sync_every, blk, eps, chunk,
                base, delta, ticks, skb, skd, policy)
        else:
            a, base, delta, ticks, skb, skd = _porc_multisource_scan(
                span, n_bins, S, sync_every, blk, eps, chunk, engine,
                base, delta, ticks, skb, skd, policy)
        routed = routed + length * S
        parts.append(a)
        off += length * S
    if r:
        keys_pad = jnp.concatenate(
            [keys[off:], jnp.zeros((S - r,), keys.dtype)])
        a, base, delta, skb, skd = _porc_multisource_tail(
            keys_pad, n_bins, S, eps, chunk, base, delta, jnp.float32(r),
            skb, skd, policy)
        routed = routed + r
        ticks = jnp.zeros_like(ticks)    # tail publish = a merge
        parts.append(a[:r])
    if not parts:
        assign = jnp.zeros((0,), jnp.int32)
    else:
        assign = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return assign, MultiSourcePorcState(base=base, delta=delta,
                                        routed=routed, ticks=ticks,
                                        sketch_base=skb, sketch_delta=skd)


def multisource_merge(state: MultiSourcePorcState) -> MultiSourcePorcState:
    """Force a synchronization: publish every source's delta into the
    base (e.g. at a monitoring-slot boundary, where the paper's
    piggybacked signals all arrive) and restart the sync phase. The
    sketch lanes, when present, merge the same way (the sketch is
    linear, so this is exact)."""
    return MultiSourcePorcState(
        base=state.base + state.delta.sum(0),
        delta=jnp.zeros_like(state.delta),
        routed=state.routed,
        ticks=jnp.zeros_like(state.ticks),
        sketch_base=(None if state.sketch_base is None
                     else state.sketch_base + state.sketch_delta.sum(0)),
        sketch_delta=(None if state.sketch_delta is None
                      else jnp.zeros_like(state.sketch_delta)))


# ---------------------------------------------------------------------------
# CG MoE dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_experts", "k", "capacity", "block"))
def ref_cg_dispatch(pref: jnp.ndarray, gates: jnp.ndarray, *, n_experts: int,
                    k: int, capacity: int | None = None,
                    capacities: jnp.ndarray | None = None, block: int = 128):
    """Oracle for kernels.cg_dispatch.

    Args:
      pref: [T, D] experts per token sorted by gate desc (D ≥ k gives the
        overflow depth — the PoRC salted-probe sequence analogue).
      gates: [T, D] matching gate scores (softmax probs).
      capacity: uniform per-expert buffer size C (the scalar special
        case; bit-identical to ``capacities=full(E, C)``).
      capacities: [E] per-expert buffer sizes — the paper's
        heterogeneous-cluster capacities (Fig 15) on the expert axis.
        Exactly one of ``capacity`` / ``capacities`` must be given.
    Returns:
      expert_assign [T, k] int32 (-1 = unplaced), slot [T, k] int32
      (position in the expert's buffer, < cap_e), weights [T, k] f32
      (renormalized over placed slots), load [E] f32 final per-expert
      occupancy.
    """
    T, D = pref.shape
    assert T % block == 0
    if (capacity is None) == (capacities is None):
        raise ValueError("pass exactly one of capacity / capacities")
    cap_vec = (jnp.full((n_experts,), capacity, jnp.float32)
               if capacities is None
               else jnp.asarray(capacities, jnp.float32))

    def blk(load, xs):
        p, g = xs                                            # [B, D]
        B = p.shape[0]
        assign = jnp.full((B, k), -1, jnp.int32)
        slot = jnp.full((B, k), -1, jnp.int32)
        wts = jnp.zeros((B, k), jnp.float32)
        nacc = jnp.zeros((B,), jnp.int32)

        def rank_step(r, carry):
            load, assign, slot, wts, nacc = carry
            c = p[:, r]
            want = nacc < k
            onehot = (c[:, None] == jnp.arange(n_experts)[None, :]) & want[:, None]
            pos = jnp.cumsum(onehot.astype(jnp.float32), axis=0) - onehot
            mypos = jnp.take_along_axis(pos, c[:, None], axis=1)[:, 0]
            myload = load[c] + mypos
            accept = want & (myload < cap_vec[c])
            col = (jnp.arange(k)[None, :] == nacc[:, None]) & accept[:, None]
            assign = jnp.where(col, c[:, None], assign)
            slot = jnp.where(col, myload.astype(jnp.int32)[:, None], slot)
            wts = jnp.where(col, g[:, r][:, None], wts)
            load = load + jnp.sum(
                onehot.astype(jnp.float32) * accept[:, None], axis=0)
            return load, assign, slot, wts, nacc + accept.astype(jnp.int32)

        load, assign, slot, wts, nacc = jax.lax.fori_loop(
            0, D, rank_step, (load, assign, slot, wts, nacc))
        denom = jnp.maximum(jnp.sum(wts, -1, keepdims=True), 1e-9)
        return load, (assign, slot, wts / denom)

    load0 = jnp.zeros((n_experts,), jnp.float32)
    load, (assign, slot, wts) = jax.lax.scan(
        blk, load0, (pref.reshape(-1, block, D), gates.reshape(-1, block, D)))
    return (assign.reshape(T, k), slot.reshape(T, k),
            wts.reshape(T, k), load)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ref_ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Exact sequential SSD recurrence (the gold semantics).

    h_t = exp(dt_t·A_h)·h_{t-1} + dt_t·(x_t ⊗ B_t);  y_t = h_t·C_t

    Args:
      x:  [B, L, H, P] inputs per head.
      dt: [B, L, H] positive step sizes.
      A:  [H] negative decay rates.
      Bm: [B, L, G, N] input projections (G groups, H % G == 0).
      Cm: [B, L, G, N] output projections.
    Returns y: [B, L, H, P].
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)                         # [B, L, H, N]
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(h, xs):
        xt, dtt, bt, ct = xs                                  # [B,H,P],[B,H],[B,H,N]x2
        decay = jnp.exp(dtt * A[None, :])[..., None, None]    # [B,H,1,1]
        h = decay * h + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Ch, 1, 0).astype(jnp.float32))
    _, y = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(y, 0, 1).astype(x.dtype)
