"""Backend detection shared by the Pallas kernel wrappers.

Two knobs resolve here:

* ``interpret`` — every Pallas entry point takes ``interpret=None``
  meaning *auto*: compile to Mosaic on TPU, run the kernel body under
  the Pallas interpreter everywhere else (CPU CI, unit tests). Passing
  an explicit bool still forces either mode (the parity tests pin
  ``interpret=True`` so they exercise the kernel path on any backend).
* ``engine`` — the user-facing routing-engine selector
  (``partitioners.route``, ``CGConfig.engine``,
  ``serve.CGRequestRouter``): ``"ref"`` is the jnp block engine,
  ``"pallas"`` the Pallas block engine, ``"auto"`` picks Pallas on TPU
  and jnp elsewhere (on CPU the interpreted kernel is strictly slower
  than the jnp scan — same math, per-op interpreter overhead — so auto
  never pays it). The internal names ``"snapshot"``/``"strict"`` pass
  through for callers addressing ``kernels.ref`` directly.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → auto: compiled on TPU, interpreter elsewhere."""
    return not on_tpu() if interpret is None else interpret


def resolve_engine(engine: str) -> str:
    """Map an engine knob to the concrete block engine to run."""
    if engine in ("ref", "jnp"):
        return "snapshot"
    if engine == "auto":
        return "pallas" if on_tpu() else "snapshot"
    if engine in ("snapshot", "strict", "pallas"):
        return engine
    raise ValueError(
        f"unknown engine {engine!r}: expected 'ref' | 'pallas' | 'auto' "
        "(or the internal 'snapshot' | 'strict')")
