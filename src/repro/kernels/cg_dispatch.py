"""Pallas TPU kernel: CG MoE dispatch — capacity-bounded with overflow.

The paper's chromatic balls-and-bins (§VI-A-1) instantiated as an MoE
token router: tokens are balls, experts are bins, expert capacity is the
(1+ε)·avg bound. Unlike standard top-k routing (which *drops* tokens at
full experts), an overflowing token-slot diverts to the token's
next-preferred expert with spare capacity — PoRC's salted-probe
sequence, with the gate-sorted expert list playing the hash sequence.

Grid: (T // block,) sequential; per-expert load in VMEM scratch.
Semantics bit-identical to ``ref.ref_cg_dispatch``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret


def _dispatch_kernel(pref_ref, gates_ref, caps_ref, assign_ref, slot_ref,
                     wts_ref, loadout_ref, load_ref, *, n_experts: int,
                     k: int, block: int, n_blocks: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        load_ref[...] = jnp.zeros_like(load_ref)

    p = pref_ref[...]                                     # [B, D]
    g = gates_ref[...]
    caps = caps_ref[...]                                  # [E] f32
    D = p.shape[1]
    load = load_ref[...]
    experts = jnp.arange(n_experts, dtype=jnp.int32)

    assign = jnp.full((block, k), -1, jnp.int32)
    slot = jnp.full((block, k), -1, jnp.int32)
    wts = jnp.zeros((block, k), jnp.float32)
    nacc = jnp.zeros((block,), jnp.int32)

    def rank_step(r, carry):
        load, assign, slot, wts, nacc = carry
        c = jax.lax.dynamic_index_in_dim(p, r, axis=1, keepdims=False)
        gr = jax.lax.dynamic_index_in_dim(g, r, axis=1, keepdims=False)
        want = nacc < k
        onehot = (c[:, None] == experts[None, :]) & want[:, None]
        oh = onehot.astype(jnp.float32)
        pos = jnp.cumsum(oh, axis=0) - oh
        mypos = jnp.sum(pos * oh, axis=1)
        myload = jnp.sum(load[None, :] * oh, axis=1) + mypos
        mycap = jnp.sum(caps[None, :] * oh, axis=1)
        accept = want & (myload < mycap)
        col = (jnp.arange(k)[None, :] == nacc[:, None]) & accept[:, None]
        assign = jnp.where(col, c[:, None], assign)
        slot = jnp.where(col, myload.astype(jnp.int32)[:, None], slot)
        wts = jnp.where(col, gr[:, None], wts)
        load = load + jnp.sum(oh * accept[:, None].astype(jnp.float32), axis=0)
        return load, assign, slot, wts, nacc + accept.astype(jnp.int32)

    load, assign, slot, wts, nacc = jax.lax.fori_loop(
        0, D, rank_step, (load, assign, slot, wts, nacc))

    denom = jnp.maximum(jnp.sum(wts, axis=-1, keepdims=True), 1e-9)
    assign_ref[...] = assign
    slot_ref[...] = slot
    wts_ref[...] = wts / denom
    load_ref[...] = load

    @pl.when(b == n_blocks - 1)
    def _flush():
        loadout_ref[...] = load_ref[...]


@functools.partial(jax.jit, static_argnames=("n_experts", "k", "capacity",
                                             "block", "interpret"))
def cg_dispatch(pref: jnp.ndarray, gates: jnp.ndarray, *, n_experts: int,
                k: int, capacity: int | None = None,
                capacities: jnp.ndarray | None = None, block: int = 128,
                interpret: bool | None = None):
    """Capacity-bounded MoE assignment with CG overflow.

    Args:
      pref: [T, D] int32 — experts sorted by gate desc (D ≥ k; D−k is the
        overflow probe depth).
      gates: [T, D] f32 — matching gate probabilities.
      capacity: uniform per-expert buffer size C (scalar special case,
        bit-identical to ``capacities=full(E, C)``).
      capacities: [E] per-expert buffer sizes (heterogeneous experts);
        exactly one of ``capacity`` / ``capacities`` must be given.
    Returns (expert_assign [T,k], slot [T,k], weights [T,k], load [E]).
    """
    T, D = pref.shape
    assert T % block == 0, f"{T} % {block} != 0"
    if (capacity is None) == (capacities is None):
        raise ValueError("pass exactly one of capacity / capacities")
    cap_vec = (jnp.full((n_experts,), capacity, jnp.float32)
               if capacities is None
               else jnp.asarray(capacities, jnp.float32))
    n_blocks = T // block
    kernel = functools.partial(_dispatch_kernel, n_experts=n_experts, k=k,
                               block=block, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block, D), lambda b: (b, 0)),
            pl.BlockSpec((block, D), lambda b: (b, 0)),
            pl.BlockSpec((n_experts,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda b: (b, 0)),
            pl.BlockSpec((block, k), lambda b: (b, 0)),
            pl.BlockSpec((block, k), lambda b: (b, 0)),
            pl.BlockSpec((n_experts,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((n_experts,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_experts,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(pref, gates, cap_vec)
