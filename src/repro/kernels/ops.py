"""Public jit'd entry points for the Pallas kernels.

On TPU the kernels compile to Mosaic; everywhere else (this CPU
container, unit tests) they run in interpret mode, which executes the
kernel body with real JAX ops — same semantics, validated against the
``ref`` oracles. The backend choice lives in the kernels themselves
now (``interpret=None`` → auto, see ``kernels.backend``); these
wrappers just re-export the auto-mode call.
"""
from __future__ import annotations

import jax.numpy as jnp

from .cg_dispatch import cg_dispatch as _cg_dispatch
from .porc_assign import porc_assign as _porc_assign
from .porc_snapshot import porc_snapshot as _porc_snapshot
from .ssd_scan import ssd_scan as _ssd_scan


def porc_assign(keys: jnp.ndarray, n_bins: int, *, d: int | None = None,
                block: int = 128, eps: float = 0.05, m0: float = 0.0,
                load0: jnp.ndarray | None = None):
    """Block-synchronous PoRC routing (paper Alg. 1, TPU-adapted):
    the rank-sequential strict-cap kernel."""
    return _porc_assign(keys, n_bins, d=d, block=block, eps=eps, m0=m0,
                        load0=load0)


def porc_snapshot(keys: jnp.ndarray, n_bins: int, *, block: int = 128,
                  eps: float = 0.05, chunk: int = 8, m0: float = 0.0,
                  load0: jnp.ndarray | None = None):
    """Snapshot-probing PoRC block engine (the fast path) as a Pallas
    kernel — bit-identical to ``ref.ref_porc_snapshot``."""
    return _porc_snapshot(keys, n_bins, block=block, eps=eps, chunk=chunk,
                          m0=m0, load0=load0)


def cg_dispatch(pref: jnp.ndarray, gates: jnp.ndarray, *, n_experts: int,
                k: int, capacity: int, block: int = 128):
    """Capacity-bounded MoE assignment with CG overflow."""
    return _cg_dispatch(pref, gates, n_experts=n_experts, k=k,
                        capacity=capacity, block=block)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD chunked scan."""
    return _ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
