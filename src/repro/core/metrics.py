"""Evaluation metrics from the paper (Table III).

All metrics take the *assignment* produced by a partitioner plus
capacities, and are pure jnp so benchmarks can jit them.
"""
from __future__ import annotations

import jax.numpy as jnp


def loads(assignment: jnp.ndarray, n_bins: int,
          weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """L_w = number (or weight) of messages assigned to each bin."""
    if weights is None:
        weights = jnp.ones_like(assignment, dtype=jnp.float32)
    return jnp.zeros(n_bins, jnp.float32).at[assignment].add(weights)


def normalized_loads(assignment: jnp.ndarray, capacities: jnp.ndarray) -> jnp.ndarray:
    """U_w = L_w / c_w (paper §IV)."""
    L = loads(assignment, capacities.shape[0])
    return L / capacities


def imbalance(assignment: jnp.ndarray, capacities: jnp.ndarray) -> jnp.ndarray:
    """I(t) = max_w U_w − avg_w U_w."""
    U = normalized_loads(assignment, capacities)
    return jnp.max(U) - jnp.mean(U)


def normalized_imbalance(assignment: jnp.ndarray, capacities: jnp.ndarray) -> jnp.ndarray:
    """Imbalance divided by the average normalized load (plot-friendly)."""
    U = normalized_loads(assignment, capacities)
    return (jnp.max(U) - jnp.mean(U)) / jnp.maximum(jnp.mean(U), 1e-12)


def memory_footprint(assignment: jnp.ndarray, keys: jnp.ndarray,
                     n_bins: int, n_keys: int) -> jnp.ndarray:
    """Sum over bins of unique keys present = total key replication.

    M = Σ_w |{k : k appears at w}|. Computed via a (n_keys, n_bins)
    presence matrix, so callers should keep n_keys·n_bins modest
    (benchmarks use ≤ 1e8 cells) — fine for the paper's scales.
    """
    assert n_keys * n_bins < 2**31, "presence matrix would overflow int32"
    flat = keys.astype(jnp.int32) * n_bins + assignment.astype(jnp.int32)
    present = jnp.zeros(n_keys * n_bins, jnp.int32).at[flat].max(1)
    return jnp.sum(present)


def replication_lower_bound(p: jnp.ndarray, n_bins: int, eps: float) -> jnp.ndarray:
    """Paper Eq. 2: E[X] = Σ_i ceil(p_i · n / (1+eps)) (PoRC bound)."""
    return jnp.sum(jnp.ceil(p * n_bins / (1.0 + eps)))


def replication_upper_bound_sg(p: jnp.ndarray, m: int, n_bins: int) -> jnp.ndarray:
    """Paper Eq. 1: E[X] = Σ_i min(ceil(p_i·m), n) (shuffle grouping)."""
    return jnp.sum(jnp.minimum(jnp.ceil(p * m), n_bins))
