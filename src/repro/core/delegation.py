"""Capacity-weighted worker delegation — the shared rebalance engine.

The paper's delegation half of CG (§V-B "pairing virtual workers",
§V-C monitoring/piggybacking) in one jit-able engine, shared by the
simulator (``core.cg``), the serving router (``serve.engine``) and the
straggler balancer (``runtime.straggler``) — previously three divergent
implementations.

Semantics
---------
* **Windowed load rates.** Per-VW arrival rates are tracked as an
  exponentially-windowed sum ``rate ← rate_decay·rate + arrivals`` with
  effective window ≈ 1/(1−rate_decay) monitoring slots.
  ``rate_decay=1.0`` keeps the cumulative-since-t₀ counts of the seed
  implementation (and the paper's m_t bookkeeping); < 1 makes the
  migration choice and the capacity-weighted budgets track *recent*
  traffic, which is what lets the engine follow Fig 12/13's
  time-varying capacities instead of averaging over the whole past.
* **Severity order with FCFS carry-over.** Busy and idle signals enter
  per-worker queues; pairing order is FIFO over *enqueue slot* with
  ties (signals that arrived in the same slot) broken by severity —
  exactly the degenerate-FCFS argument of §V-B, but the queues now
  survive across slots (``fcfs=True``): a busy worker that the move
  budget could not serve this slot keeps its place at the head of the
  queue next slot, the paper's queue behaviour that previously lived
  only in ``runtime/straggler.py``. ``fcfs=False`` rebuilds the queues
  from the current signals each slot (the seed behaviour).
* **Capacity-proportional move budgets.** With
  ``capacity_weighted=True`` a busy worker sheds as many VWs as its
  rate surplus over its capacity-proportional share
  (``round((R_w − c_w/Σc·R)/​(R/V))``, clipped to what it owns), and an
  idle worker absorbs up to its deficit — a 0.3×-speed worker drains to
  the fleet's normalized utilization in one or two slots instead of one
  VW per slot. ``capacity_weighted=False`` is the seed's one-VW-per-pair
  pacing. Either way at most ``max_moves_per_slot`` moves execute per
  slot and **only executed moves** consume budget: a busy worker that
  owns no VWs is skipped (run-length zero in the schedule), it does not
  burn the pair's slot like the seed pairing reference did
  (``seed_pairing_reference`` below preserves that quirk as the parity
  specification). ``rebalance_step``/``plan_pairs`` also accept a
  runtime ``budget`` below the static ceiling — the adaptive
  queue-depth budgets of ``repro.core.controller``.
* **Device residency.** The owner map, rates and queues are jnp arrays
  threaded through ``rebalance_step`` (fully jit-compiled); callers
  never loop over VWs on the host.
* **Migration cost (bytes moved).** Flipping the owner map is free only
  for stateless operators; a stateful VW (keyed session state, KV
  cache) pays a transfer proportional to its state size
  (arXiv:1610.05121 makes this the first-class rebalancing term).
  Passing ``vw_bytes`` ([V] f32 per-VW state sizes) to
  ``rebalance_step`` turns it on: cumulative bytes moved are tracked in
  ``DelegationState.bytes_moved``, ``byte_budget_per_slot`` caps the
  bytes one slot may transfer (moves that would overflow it are
  skipped, budget left for smaller VWs later in the schedule), and
  ``min_gain_per_byte`` is the cost-benefit test — a VW only moves if
  its rate (the traffic relief) amortizes its transfer
  (``rate ≥ min_gain_per_byte · bytes``). With ``vw_bytes=None`` (the
  default) or both knobs at 0 the planner is bit-identical to the
  cost-free engine. ``evacuate`` is the exception: a dead worker's VWs
  always move (there is no cheaper option than off a corpse), the
  bytes are accounted but never gated.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

NOT_QUEUED = jnp.iinfo(jnp.int32).max     # sorts after every real slot


class DelegationConfig(NamedTuple):
    n_workers: int
    n_virtual: int                 # 0 is fine for pairing-only use
    max_moves_per_slot: int = 8
    capacity_weighted: bool = False  # budgets ∝ rate surplus/deficit
    rate_decay: float = 1.0        # EWMA decay of per-VW rates
                                   # (1.0 = cumulative, the seed behaviour)
    fcfs: bool = False             # carry unpaired signals across slots
    byte_budget_per_slot: float = 0.0  # max VW state bytes one slot may
                                   # migrate (0 = unmetered); only
                                   # effective when vw_bytes is passed
    min_gain_per_byte: float = 0.0  # cost-benefit: move a VW only if
                                   # rate ≥ this · its state bytes


class PairQueues(NamedTuple):
    """FCFS signal queues: the slot each worker entered the busy/idle
    queue (``NOT_QUEUED`` = not enqueued) plus the slot counter."""
    busy_since: jnp.ndarray   # [n] i32
    idle_since: jnp.ndarray   # [n] i32
    slot: jnp.ndarray         # []  i32


class DelegationState(NamedTuple):
    vw_owner: jnp.ndarray     # [V] i32 physical worker owning each VW
    vw_rate: jnp.ndarray      # [V] f32 windowed per-VW arrival rate
    queues: PairQueues
    moves: jnp.ndarray        # []  i32 cumulative executed moves
    bytes_moved: jnp.ndarray | float = 0.0  # [] f32 cumulative VW state
                              # bytes migrated (stays 0 unless the
                              # caller passes vw_bytes)


def init_queues(n_workers: int) -> PairQueues:
    return PairQueues(
        busy_since=jnp.full((n_workers,), NOT_QUEUED, jnp.int32),
        idle_since=jnp.full((n_workers,), NOT_QUEUED, jnp.int32),
        slot=jnp.zeros((), jnp.int32))


def init_state(cfg: DelegationConfig,
               vw_owner: jnp.ndarray | None = None) -> DelegationState:
    if vw_owner is None:
        vw_owner = jnp.tile(
            jnp.arange(cfg.n_workers, dtype=jnp.int32),
            max(1, cfg.n_virtual // max(cfg.n_workers, 1)))[: cfg.n_virtual]
    return DelegationState(
        vw_owner=jnp.asarray(vw_owner, jnp.int32),
        vw_rate=jnp.zeros((cfg.n_virtual,), jnp.float32),
        queues=init_queues(cfg.n_workers),
        moves=jnp.zeros((), jnp.int32),
        bytes_moved=jnp.zeros((), jnp.float32))


def _enqueue(cfg: DelegationConfig, busy, idle, q: PairQueues):
    """Admit this slot's signals into the FCFS queues. A worker whose
    signal flips is dequeued from the opposite queue; with ``fcfs``
    off the queues are rebuilt from the current signals (seed mode)."""
    if cfg.fcfs:
        b = jnp.where(busy & (q.busy_since == NOT_QUEUED), q.slot,
                      q.busy_since)
        b = jnp.where(idle, NOT_QUEUED, b)
        i = jnp.where(idle & (q.idle_since == NOT_QUEUED), q.slot,
                      q.idle_since)
        i = jnp.where(busy, NOT_QUEUED, i)
        return b, i
    return (jnp.where(busy, q.slot, NOT_QUEUED),
            jnp.where(idle, q.slot, NOT_QUEUED))


def _fcfs_rank(enq, severity):
    """Queued workers first, ordered by (enqueue slot asc, severity asc),
    ties by worker index — the FCFS queue with in-slot severity order.
    ``severity`` must already be ascending-is-first (negate for busy)."""
    sev = jnp.where(enq == NOT_QUEUED, jnp.inf, severity)
    order = jnp.argsort(sev, stable=True)
    return order[jnp.argsort(enq[order], stable=True)]


def _budgets(cfg: DelegationConfig, owned_count, rate_w, in_busy, in_idle,
             capacities):
    """Per-worker shed/absorb budgets (VW counts) for this slot."""
    one = jnp.minimum(owned_count, 1)
    if not cfg.capacity_weighted:
        shed = jnp.where(in_busy, one, 0)
        absorb = jnp.where(in_idle, 1, 0)
        return shed.astype(jnp.int32), absorb.astype(jnp.int32)
    total = jnp.sum(rate_w)
    share = capacities / jnp.maximum(jnp.sum(capacities), 1e-9)
    target = share * total                       # capacity-proportional
    per_vw = jnp.maximum(total / max(cfg.n_virtual, 1), 1e-9)
    surplus = jnp.round((rate_w - target) / per_vw).astype(jnp.int32)
    deficit = jnp.round((target - rate_w) / per_vw).astype(jnp.int32)
    # a busy signal always sheds at least one VW if it owns any (the
    # FCFS pacing floor — the seed behaviour is the lower bound), and
    # never more than it owns; an idle signal absorbs at least one.
    shed = jnp.where(in_busy, jnp.clip(surplus, one, owned_count), 0)
    absorb = jnp.where(in_idle, jnp.maximum(deficit, 1), 0)
    return shed.astype(jnp.int32), absorb.astype(jnp.int32)


def _schedule(cfg: DelegationConfig, busy_rank, idle_rank, shed, absorb):
    """Expand per-worker budgets into per-move (src, dst) sequences.

    Move j draws its source from the run-length decoding of the shed
    budgets in FCFS/severity order (a worker with budget 0 — e.g. no
    VWs — occupies zero run length, i.e. is skipped for free) and its
    destination from the absorb budgets likewise.
    """
    M = cfg.max_moves_per_slot
    last = max(cfg.n_workers - 1, 0)
    cs = jnp.cumsum(shed[busy_rank])
    ca = jnp.cumsum(absorb[idle_rank])
    j = jnp.arange(M, dtype=jnp.int32)
    src = busy_rank[jnp.clip(jnp.searchsorted(cs, j, side="right"), 0, last)]
    dst = idle_rank[jnp.clip(jnp.searchsorted(ca, j, side="right"), 0, last)]
    n_exec = jnp.minimum(jnp.minimum(cs[-1], ca[-1]),
                         jnp.int32(M)).astype(jnp.int32)
    return src, dst, n_exec


def _execute(cfg: DelegationConfig, vw_owner, vw_rate, src, dst, n_exec,
             vw_bytes=None):
    """Apply the scheduled moves: each move re-homes the source worker's
    highest-rate VW (greatest relief). Sequential because a worker
    shedding k VWs must pick its top-k one at a time as ownership
    changes under it.

    With ``vw_bytes`` given, moves additionally pay migration cost: a VW
    is only *eligible* if its rate amortizes its state transfer
    (``rate ≥ min_gain_per_byte · bytes``), and a move whose VW would
    push the slot past ``byte_budget_per_slot`` is skipped (the budget
    is left for smaller VWs later in the schedule). Skipped moves don't
    count as executed. ``vw_bytes=None`` compiles the cost-free path.
    """
    n = cfg.n_workers
    neg_inf = jnp.float32(-jnp.inf)
    metered = vw_bytes is not None
    if metered:
        vw_bytes = jnp.asarray(vw_bytes, jnp.float32)
        eligible_vw = vw_rate >= cfg.min_gain_per_byte * vw_bytes

    def body(j, carry):
        owner, done, served_src, served_dst, nbytes = carry
        s, d = src[j], dst[j]
        owned = owner == s
        cand = owned & eligible_vw if metered else owned
        v = jnp.argmax(jnp.where(cand, vw_rate, neg_inf))
        can = (j < n_exec) & jnp.any(cand)
        if metered and cfg.byte_budget_per_slot > 0:
            can = can & (nbytes + vw_bytes[v] <= cfg.byte_budget_per_slot)
        owner = owner.at[v].set(jnp.where(can, d, owner[v]).astype(owner.dtype))
        step = can.astype(jnp.int32)
        if metered:
            nbytes = nbytes + jnp.where(can, vw_bytes[v], 0.0)
        return (owner, done + step,
                served_src.at[s].add(step), served_dst.at[d].add(step),
                nbytes)

    zeros = jnp.zeros((n,), jnp.int32)
    return jax.lax.fori_loop(
        0, cfg.max_moves_per_slot, body,
        (vw_owner, jnp.int32(0), zeros, zeros, jnp.zeros((), jnp.float32)))


def seed_pairing_reference(n, max_moves, vw_load, vw_owner, util,
                           theta_busy=0.85, theta_idle=0.75):
    """The seed pairing reference — a NumPy specification of the seed
    simulator's pairing semantics, which the uniform-capacity engine is
    gated against (tests and ``benchmarks/bench_heterogeneous``'s
    parity gate both use it).

    One VW per busy/idle pair in severity order, the migrated VW is the
    busy worker's most loaded, and — deliberately preserved — a busy
    worker owning no VWs *burns* its pairing slot. The engine fixes
    that last behaviour (run-length-zero skip), so parity holds exactly
    on scenarios where every busy worker owns at least one VW.
    """
    busy, idle = util > theta_busy, util < theta_idle
    n_pairs = min(busy.sum(), idle.sum(), max_moves)
    busy_rank = np.argsort(np.where(busy, -util, np.inf), kind="stable")
    idle_rank = np.argsort(np.where(idle, util, np.inf), kind="stable")
    owner, done = vw_owner.copy(), 0
    for i in range(min(max_moves, n)):
        src, dst = busy_rank[i], idle_rank[i]
        owned = owner == src
        if i < n_pairs and owned.any():
            owner[np.argmax(np.where(owned, vw_load, -np.inf))] = dst
            done += 1
    return owner, done


@functools.partial(jax.jit, static_argnames=("cfg",))
def plan_pairs(cfg: DelegationConfig, queues: PairQueues, pressure,
               busy, idle, budget=None, unit_bytes=None):
    """Pairing-only entry point (no owner map): returns the (src, dst)
    move schedule with unit budgets, for callers that execute moves
    themselves (e.g. the straggler balancer moving pipeline shards).

    Args:
      queues: persistent ``PairQueues`` (FCFS carry-over when cfg.fcfs).
      pressure: [n] f32, higher = more overloaded (orders busy workers
        descending and idle workers ascending).
      busy/idle: [n] bool signal masks for this slot.
      budget: optional i32 — this slot's move budget (e.g. from
        ``controller.controller_step``), clamped by
        ``max_moves_per_slot``; None keeps the static budget. An [n]
        vector is taken as per-worker shed caps instead (a worker with
        cap 0 moves nothing but keeps its FCFS queue position).
      unit_bytes: optional f32 scalar — the state bytes one move
        transfers (callers without per-VW accounting use the mean shard
        state size). With ``cfg.byte_budget_per_slot > 0`` the pair
        count is clamped so ``n_pairs · unit_bytes`` stays within the
        byte budget, floored at one pair (matching
        ``controller_step``'s byte clamp) so a unit larger than the
        budget rate-limits to one move per slot instead of wedging
        callers that rely on forward progress; None skips the byte
        clamp.

    Returns (src [M] i32, dst [M] i32, n_pairs i32, new PairQueues);
    only the first ``n_pairs`` schedule entries are valid.
    """
    pressure = jnp.asarray(pressure, jnp.float32)
    busy_since, idle_since = _enqueue(cfg, busy, idle, queues)
    busy_rank = _fcfs_rank(busy_since, -pressure)
    idle_rank = _fcfs_rank(idle_since, pressure)
    shed = (busy_since != NOT_QUEUED).astype(jnp.int32)
    absorb = (idle_since != NOT_QUEUED).astype(jnp.int32)
    shed_cap, n_exec_cap = shed, None
    if budget is not None:
        budget = jnp.asarray(budget, jnp.int32)
        if budget.ndim:        # [n] per-worker caps (0 = hold in queue)
            shed_cap = jnp.minimum(shed, budget)
        else:
            n_exec_cap = budget
    src, dst, n_exec = _schedule(cfg, busy_rank, idle_rank, shed_cap,
                                 absorb)
    if n_exec_cap is not None:
        n_exec = jnp.minimum(n_exec, n_exec_cap)
    if unit_bytes is not None and cfg.byte_budget_per_slot > 0:
        fit = jnp.floor(cfg.byte_budget_per_slot
                        / jnp.maximum(jnp.asarray(unit_bytes, jnp.float32),
                                      1e-9)).astype(jnp.int32)
        n_exec = jnp.minimum(n_exec, jnp.maximum(fit, 1))
    lt = jnp.arange(cfg.max_moves_per_slot, dtype=jnp.int32) < n_exec
    served_src = jnp.zeros((cfg.n_workers,), jnp.int32).at[src].add(
        lt.astype(jnp.int32))
    served_dst = jnp.zeros((cfg.n_workers,), jnp.int32).at[dst].add(
        lt.astype(jnp.int32))
    busy_since = jnp.where(served_src >= shed, NOT_QUEUED, busy_since)
    idle_since = jnp.where(served_dst >= absorb, NOT_QUEUED, idle_since)
    return src, dst, n_exec, PairQueues(busy_since, idle_since,
                                        queues.slot + 1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def rebalance_step(cfg: DelegationConfig, state: DelegationState, pressure,
                   busy, idle, vw_arrivals, capacities, budget=None,
                   vw_bytes=None):
    """One monitoring-slot tick of the full engine.

    Updates the windowed VW rates from this slot's arrivals, admits the
    signals into the FCFS queues, computes (capacity-weighted) move
    budgets, schedules busy→idle pairs in severity/FCFS order and
    executes them on the device-resident owner map.

    Args:
      pressure: [n] f32 severity (e.g. utilization or queue occupancy).
      busy/idle: [n] bool delegation signals.
      vw_arrivals: [V] f32 per-VW arrivals since the previous tick.
      capacities: [n] f32 service-rate estimates (any scale — only the
        shares matter); ignored unless ``cfg.capacity_weighted``.
      budget: optional i32 — this slot's move budget, typically derived
        from queue depth by ``controller.controller_step``. A scalar
        clamps the slot's executed-move count; an [n] vector clamps
        each worker's shed count individually (per-worker budgets — a
        worker with cap 0 moves nothing but keeps its FCFS queue
        position). The static ``max_moves_per_slot`` stays the hard
        ceiling (schedule arrays are sized by it); None keeps the
        static budget, which is bit-identical to the pre-controller
        engine.
      vw_bytes: optional [V] f32 per-VW state sizes — turns on
        migration-cost accounting: ``byte_budget_per_slot`` caps the
        bytes this slot migrates and ``min_gain_per_byte`` gates each
        move on rate/bytes (see ``_execute``). None (the default) is
        bit-identical to the cost-free engine.

    Returns (new DelegationState, n_moved i32).
    """
    pressure = jnp.asarray(pressure, jnp.float32)
    rate = cfg.rate_decay * state.vw_rate + jnp.asarray(vw_arrivals,
                                                       jnp.float32)
    busy_since, idle_since = _enqueue(cfg, busy, idle, state.queues)
    in_busy = busy_since != NOT_QUEUED
    in_idle = idle_since != NOT_QUEUED
    busy_rank = _fcfs_rank(busy_since, -pressure)
    idle_rank = _fcfs_rank(idle_since, pressure)
    n = cfg.n_workers
    owned_count = jnp.zeros((n,), jnp.int32).at[state.vw_owner].add(1)
    rate_w = jnp.zeros((n,), jnp.float32).at[state.vw_owner].add(rate)
    shed, absorb = _budgets(cfg, owned_count, rate_w, in_busy, in_idle,
                            jnp.asarray(capacities, jnp.float32))
    # ``shed`` (uncapped demand) drives the FCFS dequeue below; the
    # schedule may additionally be capped by the controller's budget —
    # a scalar clamps the executed-move count, an [n] vector clamps
    # each worker's shed count individually (per-worker budgets). A
    # budget-starved worker keeps its queue position either way.
    shed_cap, n_exec_cap = shed, None
    if budget is not None:
        budget = jnp.asarray(budget, jnp.int32)
        if budget.ndim:
            shed_cap = jnp.minimum(shed, budget)
        else:
            n_exec_cap = budget
    src, dst, n_exec = _schedule(cfg, busy_rank, idle_rank, shed_cap,
                                 absorb)
    if n_exec_cap is not None:
        n_exec = jnp.minimum(n_exec, n_exec_cap)
    owner, n_done, served_src, served_dst, n_bytes = _execute(
        cfg, state.vw_owner, rate, src, dst, n_exec, vw_bytes)
    # fully-served workers leave their queue; partially-served ones keep
    # their FCFS position for the next slot (budgets are re-derived from
    # fresh rates each slot, only membership carries over).
    busy_since = jnp.where(served_src >= shed, NOT_QUEUED, busy_since)
    idle_since = jnp.where(served_dst >= absorb, NOT_QUEUED, idle_since)
    new_state = DelegationState(
        vw_owner=owner,
        vw_rate=rate,
        queues=PairQueues(busy_since, idle_since, state.queues.slot + 1),
        moves=state.moves + n_done,
        bytes_moved=state.bytes_moved + n_bytes)
    return new_state, n_done


class VersionedOwnerMap:
    """Replicated owner map with atomic versioned commits (§V-C owner
    propagation on a mesh).

    On a multi-host mesh every source router holds a copy of the
    VW→worker map; ``rebalance_step``/``evacuate`` *commit* a new map
    atomically under a monotonically increasing version, and the head
    propagates to the routers asynchronously. A router that has not yet
    adopted the head keeps routing against the **base** view — the last
    snapshot every router is known to hold — so a stale router is
    merely conservative (it routes on the pre-move map), never torn:
    ``view()`` always returns one committed snapshot whole, no mix of
    two maps.

    Versions only move forward: ``commit`` increments, ``adopt``
    promotes head→base at the head's version. Passing ``mesh`` pins
    both snapshots replicated (``PartitionSpec()``) across the mesh's
    devices — the layout a real deployment broadcasts.
    """

    def __init__(self, owner, mesh=None):
        self._sharding = (NamedSharding(mesh, PartitionSpec())
                          if mesh is not None else None)
        owner = self._pin(jnp.asarray(owner, jnp.int32))
        self._base = owner
        self._head = owner
        self._version = 0
        self._base_version = 0

    def _pin(self, arr):
        if self._sharding is not None:
            return jax.device_put(arr, self._sharding)
        return arr

    @property
    def version(self) -> int:
        """Version of the latest committed map (monotonic)."""
        return self._version

    @property
    def base_version(self) -> int:
        """Version of the snapshot every router is known to hold."""
        return self._base_version

    def commit(self, owner) -> int:
        """Atomically publish a new owner map as the head of the next
        version. Returns the new version."""
        self._head = self._pin(jnp.asarray(owner, jnp.int32))
        self._version += 1
        return self._version

    def adopt(self) -> int:
        """Every router has received the head: promote it to base.
        Returns the adopted version."""
        self._base = self._head
        self._base_version = self._version
        return self._base_version

    def view(self, version: int | None = None) -> jnp.ndarray:
        """The snapshot a router holding ``version`` routes against:
        the head when it has the current version, else the base
        fallback. ``None`` means current."""
        if version is None or version >= self._version:
            return self._head
        return self._base


def evacuate(vw_owner, vw_rate, dead, capacities, vw_bytes=None):
    """Re-home every VW owned by the ``dead`` worker(s) onto survivors,
    capacity-proportionally — the shared dead-replica shedding path
    (serve-side replica death and train-side host loss both land here).

    A dead worker is a capacity→0 event: its target share is zero, so
    *all* of its VWs must move this instant, unmetered (no
    ``max_moves_per_slot`` pacing, no byte budget — the state transfer
    is mandatory, only accounted). VWs are assigned hottest-first to the
    survivor with the largest remaining rate *deficit* against its
    capacity-proportional share, so the evacuated traffic lands where
    the spare capacity is instead of round-robin.

    Host-side NumPy on purpose: failure is a rare event and the greedy
    deficit loop is data-dependent; the steady-state path stays the
    jitted ``rebalance_step``.

    Args:
      vw_owner: [V] int owner map (any array-like; not mutated).
      vw_rate: [V] f32 per-VW rates (the delegation engine's).
      dead: int or sequence of ints — the worker(s) being evacuated.
      capacities: [n] f32 service-rate estimates; dead entries ignored.
      vw_bytes: optional [V] f32 per-VW state sizes for the bytes-moved
        accounting.

    Returns ``(new_owner [V] np.int32, n_moved int, bytes_moved float)``.
    """
    owner = np.array(vw_owner, np.int32)
    rate = np.asarray(vw_rate, np.float64)
    if rate.sum() <= 0:
        rate = np.ones_like(rate)             # cold engine: balance counts
    n = len(np.asarray(capacities))
    dead = np.atleast_1d(np.asarray(dead, np.int64))
    alive = np.ones(n, bool)
    alive[dead] = False
    if not alive.any():
        return owner, 0, 0.0                  # nowhere to go: no-op
    caps = np.where(alive, np.asarray(capacities, np.float64), 0.0)
    if caps.sum() <= 0:
        caps = alive.astype(np.float64)       # degenerate: uniform
    evac = np.flatnonzero(np.isin(owner, dead))
    if len(evac) == 0:
        return owner, 0, 0.0
    # survivors' deficit against their capacity-proportional share of
    # the *whole* rate (the dead workers' traffic has to land somewhere)
    rate_w = np.bincount(owner, weights=np.maximum(rate, 0.0), minlength=n)
    target = caps / caps.sum() * rate_w.sum()
    deficit = np.where(alive, target - rate_w, -np.inf)
    order = evac[np.argsort(-rate[evac], kind="stable")]   # hottest first
    for v in order:
        d = int(np.argmax(deficit))
        owner[v] = d
        deficit[d] -= max(float(rate[v]), 1e-9)
    bytes_moved = (float(np.asarray(vw_bytes, np.float64)[evac].sum())
                   if vw_bytes is not None else 0.0)
    return owner, len(evac), bytes_moved
