"""Queueing simulation of workers (paper §IV cost model, Figs 9/10/13/14/15).

Arrival process: one message per unit time, routed by some partitioner.
Each worker w drains its unbounded FIFO at service rate c_w messages per
unit time. Metrics are evaluated per *slot* (the same t₀ granularity the
CG runtime monitors at), which reproduces the paper's hourly plots.

``simulate_queues`` works for any static assignment (KG/SG/PKG/...);
CG produces the same metrics inline (repro.core.cg) because its routing
changes over time.

``simulate_deployment`` is the Fig 14/15 analogue: a
throughput/latency sweep where per-message service cost is a fixed
delay (the paper emulates CPU cost with 0.1–1 ms delays) and some
executors are cpulimit-ed to a fraction of nominal speed.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class QueueSimResult(NamedTuple):
    queue_spread: jnp.ndarray    # [slots] max-min queue length
    latency_spread: jnp.ndarray  # [slots] max-min latency proxy
    mean_latency: jnp.ndarray    # [slots]
    max_latency: jnp.ndarray     # [slots] latency at the slowest worker
    imbalance: jnp.ndarray       # [slots] normalized-load imbalance
    utilization: jnp.ndarray     # [slots, n]
    throughput: jnp.ndarray      # [slots] messages drained per unit time
    final_queues: jnp.ndarray    # [n]


@functools.partial(jax.jit, static_argnames=("n_workers", "slot_len"))
def simulate_queues(assignment: jnp.ndarray, capacities: jnp.ndarray,
                    n_workers: int, slot_len: int) -> QueueSimResult:
    """Slot-stepped fluid queueing sim for a fixed routing of the stream.

    Args:
      assignment: [m] worker ids.
      capacities: [n] or [slots, n] service rates (msgs/unit-time).
    """
    m = assignment.shape[0]
    slots = m // slot_len
    a = assignment[: slots * slot_len].reshape(slots, slot_len)
    if capacities.ndim == 1:
        caps = jnp.broadcast_to(capacities, (slots, n_workers))
    else:
        caps = capacities
    caps = caps.astype(jnp.float32)

    def step(q0, xs):
        slot_a, c = xs
        arrivals = jnp.zeros(n_workers, jnp.float32).at[slot_a].add(1.0)
        service = c * slot_len
        drained = jnp.minimum(q0 + arrivals, service)
        q1 = q0 + arrivals - drained

        lat = (q0 + 0.5 * arrivals) / jnp.maximum(c, 1e-9) + 1.0 / jnp.maximum(c, 1e-9)
        mean_lat = jnp.sum(lat * arrivals) / jnp.maximum(jnp.sum(arrivals), 1.0)
        util = arrivals / jnp.maximum(service, 1e-9)
        norm_load = arrivals / jnp.maximum(c, 1e-9)
        imb = (jnp.max(norm_load) - jnp.mean(norm_load)) / jnp.maximum(
            jnp.mean(norm_load), 1e-9)
        out = (jnp.max(q1) - jnp.min(q1), jnp.max(lat) - jnp.min(lat),
               mean_lat, jnp.max(lat), imb, util,
               jnp.sum(drained) / slot_len)
        return q1, out

    q0 = jnp.zeros(n_workers, jnp.float32)
    qf, (qs, ls, ml, pl, imb, util, thr) = jax.lax.scan(step, q0, (a, caps))
    return QueueSimResult(qs, ls, ml, pl, imb, util, thr, qf)


class DeploymentResult(NamedTuple):
    throughput: jnp.ndarray      # messages/second sustained
    mean_latency_ms: jnp.ndarray
    max_latency_ms: jnp.ndarray  # latency at the worst (slowest) worker
                                 # (the fluid model has no per-message
                                 # distribution, hence no percentiles)


def simulate_deployment(assignment: jnp.ndarray, n_workers: int,
                        service_ms: float,
                        cpu_fraction: jnp.ndarray,
                        offered_rate_per_s: float) -> DeploymentResult:
    """Fig 14/15 analogue: Storm-like deployment with fixed per-message cost.

    Storm's acking backpressure (``max.spout.pending``) throttles the
    *sources* globally when any executor saturates — topology throughput
    is bound by the worst (service rate / routed share) worker:

        thr = min(offered, min_w  svc_w / share_w)

    Latency: per-worker M/D/1 wait at its realized utilization (the
    binding worker sits near ρ→1 and dominates — exactly the paper's
    observation that one overloaded executor drags end-to-end latency).

    Args:
      assignment: worker id per message (a long representative sample).
      service_ms: nominal per-message processing delay (0.1–1 ms sweep).
      cpu_fraction: [n] fraction of nominal speed (cpulimit; 1.0 = full,
        0.3 = the paper's constrained executors).
      offered_rate_per_s: messages/s offered by the sources.
    """
    m = assignment.shape[0]
    share = jnp.zeros(n_workers, jnp.float32).at[assignment].add(1.0) / m
    svc_rate = cpu_fraction / (service_ms * 1e-3)          # msgs/s per worker
    # global backpressure: the worst share/capacity worker binds everyone
    per_worker_cap = jnp.where(share > 0, svc_rate / jnp.maximum(share, 1e-9),
                               jnp.inf)
    throughput = jnp.minimum(offered_rate_per_s, jnp.min(per_worker_cap))

    arr_rate = share * throughput
    rho = jnp.clip(arr_rate / jnp.maximum(svc_rate, 1e-9), 0.0, 0.995)
    s_ms = jnp.asarray(service_ms, jnp.float32) / cpu_fraction
    wait = rho / (2.0 * (1.0 - rho)) * s_ms                # M/D/1
    lat_ms = s_ms + wait
    mean_lat = jnp.sum(lat_ms * share)
    max_lat = jnp.max(jnp.where(share > 0, lat_ms, 0.0))
    return DeploymentResult(throughput, mean_lat, max_lat)
