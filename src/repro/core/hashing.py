"""Salted 64-bit hash family used by every partitioner.

The paper uses 64-bit Murmur; we use splitmix64 (same avalanche quality,
a handful of jnp ops). Keys are integer ids; the salt implements the
paper's ``H(key + salt)`` sequence (PoRC, Alg. 1) and the independent
hash functions H_1..H_d of the Greedy-d process (by salting with the
function index).

All functions are pure jnp and jit/vmap-friendly. uint64 is enabled via
jax_enable_x64=False-safe arithmetic: we emulate 64-bit mixing with two
uint32 lanes when x64 is disabled, but jax on CPU supports uint64 ops
inside jit regardless of the x64 flag as long as we create the dtype
explicitly — to stay portable we implement splitmix in uint32-pair form.
"""
from __future__ import annotations

import jax.numpy as jnp

# plain Python ints, wrapped per call: a module-level jnp.uint32 would
# be a concrete device array, which a Pallas kernel body cannot close
# over (captured-constant error) — the whole hash family must stay
# traceable inside kernels.
_GAMMA_HI = 0x9E3779B9
_GAMMA_LO = 0x7F4A7C15


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Finalizer with strong avalanche (murmur3 fmix32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_u32(key: jnp.ndarray, salt) -> jnp.ndarray:
    """Salted 32-bit hash of integer keys. Shapes broadcast."""
    k = jnp.asarray(key).astype(jnp.uint32)
    s = jnp.asarray(salt).astype(jnp.uint32)
    h = _mix32(k + s * jnp.uint32(_GAMMA_HI))
    h = _mix32(h ^ (s * jnp.uint32(_GAMMA_LO) + jnp.uint32(0x165667B1)))
    return h


def hash_to_bins(key: jnp.ndarray, salt, n_bins: int) -> jnp.ndarray:
    """Salted hash of ``key`` into [0, n_bins). int32 result."""
    h = hash_u32(key, salt)
    return (h % jnp.uint32(n_bins)).astype(jnp.int32)


def hash_unit_interval(key: jnp.ndarray, salt) -> jnp.ndarray:
    """Salted hash onto the unit circle [0, 1) — consistent hashing ring."""
    h = hash_u32(key, salt)
    return h.astype(jnp.float32) / jnp.float32(2**32)


def candidate_bins(key: jnp.ndarray, d: int, n_bins: int) -> jnp.ndarray:
    """The first ``d`` salted choices for each key: shape key.shape + (d,).

    candidate_bins(k, d, n)[..., i] == hash_to_bins(k, i + 1, n); salts
    start at 1 to match Alg. 1 (salt <- 1).
    """
    k = jnp.asarray(key)
    salts = jnp.arange(1, d + 1, dtype=jnp.uint32)
    return hash_to_bins(k[..., None], salts, n_bins)
