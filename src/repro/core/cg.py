"""Consistent Grouping (CG) — the paper's contribution (§V-B, §V-C).

CG = (1) PoRC routing of messages onto α·n *homogeneous virtual workers*
+ (2) capacity-driven assignment of virtual workers to heterogeneous
physical workers via *worker delegation* signals and *paired* moves.

Model fidelity notes
--------------------
* **Time slot** (t₀): the monitoring period. One slot = ``slot_len``
  messages (one message per unit time, §IV). Signals computed at slot
  end take effect the next slot — this one-slot lag *is* the
  piggybacking/eventual-consistency delay of §V-C.
* **Delegation**: worker w signals *busy* when its slot utilization
  ``U_w = arrivals_w/(c_w·slot_len)`` exceeds θ_b and *idle* below θ_i
  (paper uses θ_i=0.75, θ_b=0.85 around a ρ=0.8 provisioning point).
  Capacities are **never revealed to the sources** — only the binary
  signals are.
* **Pairing**: every VW removal from a busy worker is paired with an
  addition to an idle worker (§V-B "pairing virtual workers"), keeping
  the VW population constant. Pairing runs through the shared
  ``repro.core.delegation`` engine: within a slot signals pair in
  severity order (most-overloaded busy ↔ most-underloaded idle, the
  degenerate-FCFS argument of §V-B); ``fcfs_pairing=True`` keeps
  unserved signals queued across slots (the paper's FCFS queues). The
  migrated VW is the busy worker's highest-rate one (greatest relief;
  ``rate_decay`` windows the rate — 1.0 = the seed's cumulative
  counts); ``capacity_weighted=True`` lets a busy worker shed as many
  VWs per slot as its rate surplus over its capacity-proportional
  share instead of one per signal. Routing changes affect only
  *future* messages — no message migration (§V-C).
* **Adaptive control** (``adaptive_moves``/``hysteresis``): the
  ``repro.core.controller`` layer can derive the per-slot move budget
  from EWMA'd worker queue depths (clamped to
  ``[min_moves, max_moves_per_slot]``) and latch the busy/idle signals
  between separate enter/exit levels with a dwell, damping the Fig-12
  integer ping-pong at the α-granularity boundary. Both default off —
  the defaults stay bit-identical to the seed engine.
* **Queues**: each worker drains ``c_w·slot_len`` messages per slot from
  an unbounded FIFO — the queueing model of §IV used for Fig 9/10/12/13.
* **Block-parallel routing** (``block_size``): the paper defines PoRC
  one-message-per-unit-time; the runtime routes ``block_size`` messages
  per load snapshot (``repro.kernels.ref.ref_porc_snapshot``) — the
  §V-C eventual-consistency license, same as sources with local load
  views. ``block_size=0`` keeps the exact per-message oracle;
  ``block_size=1`` takes the block path and is bit-identical to it.
* **Distributed sources** (``n_sources``/``sync_every``): §V-C's
  multiple sources become first-class — the slot's stream splits
  round-robin across ``n_sources`` sources, each routing against a
  local load view (shared base + own delta) that delta-merges every
  ``sync_every`` blocks. The slot boundary (the monitoring period t₀)
  forces a final merge: that is when the piggybacked signals all
  arrive, so no unpublished delta survives into the next slot.
  ``n_sources=1`` is exactly the single-source block path.
* **Heavy-hitter probing** (``hh_scheme``): D/W-Choices probe depths for
  the PORC inner scheme — a count-min sketch (``sketch_depth`` ×
  ``sketch_width``, carried in ``CGState.sketch``) classifies keys at
  block boundaries; keys above ``hot_fraction`` of the routed mass get
  up to ``d_heavy`` ("d") or V ("w") probe choices, the tail keeps
  ``d_tail``. Off ("") = seed-exact. Requires the block path. See
  ``repro.kernels.ref.HHPolicy`` and docs/partitioners.md.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import controller, delegation
from .hashing import hash_to_bins


class CGConfig(NamedTuple):
    n_workers: int
    alpha: int = 10               # virtual workers per worker at init
    eps: float = 0.01             # PoRC imbalance/memory knob
    theta_busy: float = 0.85
    theta_idle: float = 0.75
    slot_len: int = 10_000        # messages per time slot t0
    max_moves_per_slot: int = 8   # paired (busy→idle) moves per slot
    inner: str = "PORC"           # VW-level scheme: PORC | KG | SG
    block_size: int = 128         # PoRC messages per load snapshot;
                                  # 0 = exact per-message oracle, 1 = block
                                  # path (bit-identical to the oracle)
    n_sources: int = 1            # §V-C distributed sources routing with
                                  # local load views (round-robin split);
                                  # >1 requires the block path
    sync_every: int = 1           # blocks between delta-merge syncs of
                                  # the sources' local views
    capacity_weighted: bool = False  # delegation budgets ∝ rate surplus
                                  # over the capacity-proportional share
                                  # (False = one VW per pair, seed-exact)
    rate_decay: float = 1.0       # EWMA decay of per-VW rates per slot;
                                  # window ≈ 1/(1-decay) slots, 1.0 =
                                  # cumulative-since-t0 (seed-exact)
    fcfs_pairing: bool = False    # carry unserved busy/idle signals
                                  # across slots (the paper's queues)
    adaptive_moves: bool = False  # per-slot move budget derived from
                                  # queue depth (repro.core.controller),
                                  # clamped [min_moves, max_moves_per_slot]
                                  # (False = static budget, seed-exact)
    min_moves: int = 1            # adaptive budget floor at equilibrium
    depth_decay: float = 0.5      # EWMA decay of worker queue depths
                                  # feeding the adaptive budget
    hysteresis: bool = False      # latch busy/idle between separate
                                  # enter/exit levels + dwell (damps the
                                  # Fig-12 α-granularity ping-pong)
    theta_margin: float = 0.05    # exit-level offset: busy exits below
                                  # theta_busy-margin, idle exits above
                                  # theta_idle+margin
    dwell: int = 3                # slots a raw signal must persist
                                  # before it latches
    hh_scheme: str = ""           # heavy-hitter probe-depth policy for
                                  # the PORC inner scheme: "" = off
                                  # (seed-exact), "d" = D-Choices,
                                  # "w" = W-Choices (registry spellings
                                  # "DCHOICES"/"WCHOICES" also accepted;
                                  # requires block_size >= 1)
    sketch_depth: int = 4         # count-min sketch rows
    sketch_width: int = 4096      # count-min sketch columns per row
    hot_fraction: float = 1e-3    # heavy when sketch est >= fraction of
                                  # the routed message mass
    d_heavy: int = 32             # heavy-key probe ceiling under "d"
    d_tail: int = 2               # tail-key probe budget
    hh_headroom: float = 2.0      # probe-depth schedule slack over the
                                  # Eq.-2 spread ceil(p·n/(1+eps))
    engine: str = "auto"          # block-engine implementation for the
                                  # PORC inner scheme: "ref" (jnp scan),
                                  # "pallas" (Pallas kernel, bit-identical
                                  # — load/delta/sketch lanes in VMEM),
                                  # "auto" = Pallas on TPU, jnp elsewhere.
                                  # Applies to the block path only; the
                                  # block_size=0 sequential oracle and
                                  # KG/SG ignore it.


class CGState(NamedTuple):
    """Everything that continues across ``run`` calls / slot boundaries.

    State-carry contract: every field carries across slots *and* across
    chained ``run`` calls (``run(cfg, rest, caps, state=prev.state)`` ==
    one run over the whole stream, slot-aligned). Nothing here resets at
    slot boundaries; the only slot-boundary action is the §V-C forced
    delta-merge inside ``_route_slot`` (multi-source load views and
    sketch deltas publish at the monitoring boundary).
    """
    vw_load: jnp.ndarray     # [V]  source-side per-VW message counts
    vw_owner: jnp.ndarray    # [V]  physical worker owning each VW
    vw_rate: jnp.ndarray     # [V]  windowed per-VW arrival rate (EWMA)
    queues: jnp.ndarray      # [n]  worker FIFO occupancy
    signal_queues: delegation.PairQueues   # FCFS busy/idle queues +
                                           # slot counter (delegation)
    t_offset: jnp.ndarray    # []   messages routed so far (f32 clock)
    sg_ptr: jnp.ndarray      # []   exact SG round-robin pointer (i32,
                             #      kept in [0, V) so it never loses
                             #      precision, unlike the f32 t_offset)
    moves: jnp.ndarray       # []   cumulative paired moves
    controller: controller.ControllerState   # adaptive-budget EWMA,
                             # signal latches/dwell counters, flap count
    sketch: jnp.ndarray | None = None   # [depth, width] count-min key
                             # frequencies (heavy-hitter policy only;
                             # None when cfg.hh_scheme is off)


class DelegationTelemetry(NamedTuple):
    """Per-slot controller/engine telemetry (benchmarks consume this)."""
    budget: jnp.ndarray       # [slots] move budget the controller set
    executed: jnp.ndarray     # [slots] paired moves actually executed
    flaps: jnp.ndarray        # [slots] busy/idle signal flips this slot
    queue_depth: jnp.ndarray  # [slots, n] worker FIFO depth at slot end


class CGResult(NamedTuple):
    assignment: jnp.ndarray        # [m] physical-worker id per message
    vw_assignment: jnp.ndarray     # [m] virtual-worker id per message
    imbalance: jnp.ndarray         # [slots] I(t) over normalized load
    queue_spread: jnp.ndarray      # [slots] max-min queue length
    latency_spread: jnp.ndarray    # [slots] max-min latency proxy
    mean_latency: jnp.ndarray      # [slots] arrival-weighted mean latency
    utilization: jnp.ndarray       # [slots, n] per-worker utilization
    moves: jnp.ndarray             # [] total VW migrations
    telemetry: DelegationTelemetry  # per-slot budget/moves/flaps/depths
    state: CGState


def _hh_letter(name: str) -> str:
    """Normalize an hh_scheme spelling to the kernel letter. Accepts
    the HHPolicy letters ("d"/"w") and the partitioner-registry names
    ("DCHOICES"/"WCHOICES"), case-insensitively."""
    letter = {"d": "d", "w": "w",
              "dchoices": "d", "wchoices": "w"}.get(name.lower())
    if letter is None:
        raise ValueError(f"unknown hh_scheme {name!r}; use 'd'/'w' "
                         f"(or 'DCHOICES'/'WCHOICES')")
    return letter


def hh_policy(cfg: CGConfig):
    """The kernel ``HHPolicy`` a CGConfig's heavy-hitter knobs describe
    (None when ``hh_scheme`` is off — the seed-exact default)."""
    if not cfg.hh_scheme:
        return None
    if cfg.inner != "PORC":
        raise ValueError("hh_scheme requires the PORC inner scheme")
    if cfg.block_size < 1:
        raise ValueError("hh_scheme requires the block path "
                         "(block_size >= 1); the sketch classifies keys "
                         "at block boundaries")
    from repro.kernels.ref import HHPolicy  # deferred: core ← kernels
    return HHPolicy(scheme=_hh_letter(cfg.hh_scheme), depth=cfg.sketch_depth,
                    width=cfg.sketch_width, hot_fraction=cfg.hot_fraction,
                    d_heavy=cfg.d_heavy, d_tail=cfg.d_tail,
                    headroom=cfg.hh_headroom)


def init_state(cfg: CGConfig) -> CGState:
    n, a = cfg.n_workers, cfg.alpha
    V = n * a
    policy = hh_policy(cfg)
    if policy is not None:
        from repro.kernels.ref import hh_sketch_init
        sketch = hh_sketch_init(policy)
    else:
        sketch = None
    return CGState(
        sketch=sketch,
        vw_load=jnp.zeros(V, jnp.float32),
        vw_owner=jnp.tile(jnp.arange(n, dtype=jnp.int32), a),
        vw_rate=jnp.zeros(V, jnp.float32),
        queues=jnp.zeros(n, jnp.float32),
        signal_queues=delegation.init_queues(n),
        t_offset=jnp.zeros((), jnp.float32),
        sg_ptr=jnp.zeros((), jnp.int32),
        moves=jnp.zeros((), jnp.int32),
        controller=controller.init_controller(controller_config(cfg)),
    )


def delegation_config(cfg: CGConfig) -> delegation.DelegationConfig:
    """The shared-engine view of a CGConfig's delegation knobs."""
    return delegation.DelegationConfig(
        n_workers=cfg.n_workers,
        n_virtual=cfg.n_workers * cfg.alpha,
        max_moves_per_slot=cfg.max_moves_per_slot,
        capacity_weighted=cfg.capacity_weighted,
        rate_decay=cfg.rate_decay,
        fcfs=cfg.fcfs_pairing)


def controller_config(cfg: CGConfig) -> controller.ControllerConfig:
    """The adaptive-controller view of a CGConfig's knobs."""
    return controller.ControllerConfig(
        n_workers=cfg.n_workers,
        adaptive_moves=cfg.adaptive_moves,
        min_moves=cfg.min_moves,
        max_moves=cfg.max_moves_per_slot,
        depth_decay=cfg.depth_decay,
        hysteresis=cfg.hysteresis,
        dwell=cfg.dwell)


def _route_slot(cfg: CGConfig, vw_load, t_offset, sg_ptr, sketch, keys):
    """Route one slot of messages onto virtual workers (inner scheme).

    Returns ``(vw_load, sketch, vw)``; ``sketch`` is the heavy-hitter
    count-min state (threaded unchanged for KG/SG and when the policy is
    off, updated per block and fully published at the slot boundary for
    PORC with ``cfg.hh_scheme`` set).
    """
    V = cfg.n_workers * cfg.alpha
    policy = hh_policy(cfg)
    if cfg.inner == "KG":
        vw = hash_to_bins(keys, 1, V)
        vw_load = vw_load.at[vw].add(1.0)
        return vw_load, sketch, vw
    if cfg.inner == "SG":
        # exact int32 round-robin pointer: the f32 t_offset loses ±1
        # precision past 2^24 routed messages, which would freeze the
        # pointer; sg_ptr lives in [0, V) and never degrades.
        m = keys.shape[0]
        vw = (sg_ptr + jnp.arange(m, dtype=jnp.int32)) % V
        vw_load = vw_load.at[vw].add(1.0)
        return vw_load, sketch, vw

    if cfg.n_sources > 1:
        # §V-C distributed sources: the slot's stream splits round-robin
        # across n_sources local load views (shared merged base + own
        # delta, synchronized every sync_every blocks). The slot end is
        # the monitoring boundary, where piggybacked deltas all arrive —
        # merge them so CGState keeps a single [V] load vector.
        if cfg.block_size < 1:
            raise ValueError("n_sources > 1 requires the block path "
                             "(block_size >= 1)")
        from repro.kernels.ref import (MultiSourcePorcState,
                                       ref_porc_multisource)
        state = MultiSourcePorcState(
            base=vw_load,
            delta=jnp.zeros((cfg.n_sources, V), jnp.float32),
            routed=t_offset,
            ticks=jnp.zeros((), jnp.int32),
            sketch_base=sketch,
            sketch_delta=None if sketch is None else jnp.zeros(
                (cfg.n_sources,) + sketch.shape, jnp.float32))
        from repro.kernels import resolve_engine
        vw, state = ref_porc_multisource(
            keys, V, cfg.n_sources, sync_every=cfg.sync_every,
            block=cfg.block_size, eps=cfg.eps, state=state, policy=policy,
            engine=resolve_engine(cfg.engine))
        sketch = (None if state.sketch_base is None
                  else state.sketch_base + state.sketch_delta.sum(0))
        return state.base + state.delta.sum(0), sketch, vw

    if cfg.block_size >= 1:
        # Block-parallel PoRC: route the slot in blocks of B messages
        # against per-block load snapshots (eventually-consistent, the
        # kernels' block-synchronous semantics). Bit-identical to the
        # sequential path below when block_size == 1.
        from repro.kernels import resolve_engine
        from repro.kernels.ref import PorcState, ref_porc_route
        state = PorcState(load=vw_load, routed=t_offset, sketch=sketch)
        vw, state = ref_porc_route(keys, V, block=cfg.block_size,
                                   eps=cfg.eps, state=state, policy=policy,
                                   engine=resolve_engine(cfg.engine))
        return state.load, state.sketch, vw

    # PoRC (Alg. 1) continuing across slots: capacity uses global time.
    max_probes = 4 * V

    def step(carry, xt):
        load, t = carry
        key = xt
        cap = (1.0 + cfg.eps) * (t + 1.0) / V

        def cond(c):
            _, bin_, probes = c
            return (load[bin_] >= cap) & (probes < max_probes)

        def body(c):
            salt, _, probes = c
            salt = salt + 1
            return salt, hash_to_bins(key, salt, V), probes + 1

        init = (jnp.uint32(1), hash_to_bins(key, jnp.uint32(1), V), jnp.int32(0))
        _, bin_, probes = jax.lax.while_loop(cond, body, init)
        bin_ = jnp.where(probes >= max_probes,
                         jnp.argmin(load).astype(jnp.int32), bin_)
        return (load.at[bin_].add(1.0), t + 1.0), bin_

    (vw_load, _), vw = jax.lax.scan(step, (vw_load, t_offset), keys)
    return vw_load, sketch, vw


@functools.partial(jax.jit, static_argnames=("cfg",))
def run(cfg: CGConfig, keys: jnp.ndarray, capacities: jnp.ndarray,
        state: CGState | None = None) -> CGResult:
    """Run CG over a key stream.

    Args:
      cfg: CGConfig (n_workers, alpha, eps, thresholds, slot_len, inner).
      keys: [m] int32 key stream; m must be a multiple of slot_len.
      capacities: [n] static, or [slots, n] time-varying *service rates*
        in messages per unit time (arrival rate is 1 msg/unit time).
      state: optional CGState to continue from (e.g. ``result.state`` of
        a previous ``run`` over the stream prefix) — routing loads, the
        owner map, delegation queues and the SG pointer all carry over.
        ``capacities`` rows, if 2-D, cover only the *remaining* slots.

    Returns CGResult with per-slot metrics and the full assignment.
    """
    m = keys.shape[0]
    slots = m // cfg.slot_len
    assert slots * cfg.slot_len == m, "stream length must be slots*slot_len"
    keys = keys[: slots * cfg.slot_len].reshape(slots, cfg.slot_len)
    if capacities.ndim == 1:
        caps = jnp.broadcast_to(capacities, (slots, cfg.n_workers))
    else:
        caps = capacities
    caps = caps.astype(jnp.float32)
    dcfg = delegation_config(cfg)
    ccfg = controller_config(cfg)
    # backlog one executed move drains per slot ≈ mean per-VW arrivals
    move_unit = cfg.slot_len / max(cfg.n_workers * cfg.alpha, 1)

    def slot_step(state: CGState, xs):
        slot_keys, c = xs
        vw_load, sketch, vw = _route_slot(cfg, state.vw_load,
                                          state.t_offset, state.sg_ptr,
                                          state.sketch, slot_keys)
        workers = state.vw_owner[vw]                       # [slot_len]
        arrivals = jnp.zeros(cfg.n_workers, jnp.float32).at[workers].add(1.0)

        service = c * cfg.slot_len                          # msgs drainable
        q0 = state.queues
        q1 = jnp.maximum(q0 + arrivals - service, 0.0)

        util = arrivals / jnp.maximum(service, 1e-9)
        # latency proxy: wait behind queue + own service (units of time)
        lat = (q0 + 0.5 * arrivals) / jnp.maximum(c, 1e-9) + 1.0 / jnp.maximum(c, 1e-9)
        mean_lat = jnp.sum(lat * arrivals) / jnp.maximum(jnp.sum(arrivals), 1.0)

        norm_load = arrivals / jnp.maximum(c, 1e-9)
        imb = (jnp.max(norm_load) - jnp.mean(norm_load)) / jnp.maximum(
            jnp.mean(norm_load), 1e-9)

        # the adaptive controller turns raw pressure into (possibly
        # hysteresis-latched) busy/idle signals and this slot's move
        # budget from the EWMA'd queue depths; with both knobs off the
        # masks are the raw threshold comparisons and the budget is the
        # static ceiling (bit-identical to the pre-controller engine).
        cstate, busy, idle, budget = controller.controller_step(
            ccfg, state.controller, util, q1, move_unit,
            cfg.theta_busy, cfg.theta_busy - cfg.theta_margin,
            cfg.theta_idle, cfg.theta_idle + cfg.theta_margin)

        # worker delegation through the shared engine (§V-B pairing):
        # per-VW arrivals this slot feed the windowed rates; capacities
        # drive the capacity-proportional budgets when enabled.
        dstate = delegation.DelegationState(
            vw_owner=state.vw_owner,
            vw_rate=state.vw_rate,
            queues=state.signal_queues,
            moves=state.moves)
        dstate, n_done = delegation.rebalance_step(
            dcfg, dstate, util, busy, idle, vw_load - state.vw_load, c,
            budget if cfg.adaptive_moves else None)

        new_state = CGState(
            vw_load=vw_load,
            vw_owner=dstate.vw_owner,
            vw_rate=dstate.vw_rate,
            queues=q1,
            signal_queues=dstate.queues,
            t_offset=state.t_offset + cfg.slot_len,
            sg_ptr=(state.sg_ptr + cfg.slot_len) % (cfg.n_workers * cfg.alpha),
            moves=dstate.moves,
            controller=cstate,
            sketch=sketch,
        )
        metrics = (workers, vw, imb, jnp.max(q1) - jnp.min(q1),
                   jnp.max(lat) - jnp.min(lat), mean_lat, util,
                   budget, n_done, cstate.flaps - state.controller.flaps, q1)
        return new_state, metrics

    state0 = init_state(cfg) if state is None else state
    # normalize the sketch lane to cfg: a state carried from a policy-off
    # run cold-starts an empty sketch (scan carries need a fixed pytree
    # structure); turning the policy off drops the lane
    policy = hh_policy(cfg)
    if policy is not None and state0.sketch is None:
        from repro.kernels.ref import hh_sketch_init
        state0 = state0._replace(sketch=hh_sketch_init(policy))
    elif policy is None and state0.sketch is not None:
        state0 = state0._replace(sketch=None)
    state, (workers, vw, imb, qs, ls, ml, util,
            budget, executed, flaps, depths) = jax.lax.scan(
        slot_step, state0, (keys, caps))
    return CGResult(
        assignment=workers.reshape(-1),
        vw_assignment=vw.reshape(-1),
        imbalance=imb,
        queue_spread=qs,
        latency_spread=ls,
        mean_latency=ml,
        utilization=util,
        moves=state.moves,
        telemetry=DelegationTelemetry(budget=budget, executed=executed,
                                      flaps=flaps, queue_depth=depths),
        state=state,
    )
