"""Adaptive delegation controller — closed-loop budgets + hysteresis.

The delegation engine (``repro.core.delegation``) executes at most
``max_moves_per_slot`` paired moves per monitoring slot, and its
callers raise busy/idle signals the moment a worker's pressure crosses
a single threshold. Both are open-loop: the move budget is a constant
the operator must guess, and a worker whose ideal virtual-worker count
sits on the busy/idle boundary (the paper's Fig 12 granularity effect
at α≈10 VWs/worker) integer-ping-pongs between the two signals slot
after slot. This module closes both loops:

* **Adaptive move budgets** (``adaptive_moves=True``). The per-slot
  budget is derived from observed queue depth: per-worker depths are
  EWMA'd (``depth_decay``), the backlog *above the fleet mean* is
  converted into "how many virtual workers' worth of traffic must be
  re-homed to drain it in about one slot" (the caller supplies
  ``unit`` — the traffic one move re-routes per slot, typically
  ``slot_len / n_virtual``), and the result is clamped to
  ``[min_moves, max_moves]``. Under a flash crowd the budget opens up
  to ``max_moves`` within a couple of slots; at equilibrium it falls
  back to ``min_moves`` so steady state is not churned.
  ``per_worker_budget=True`` refines this from one fleet-wide scalar
  to an [n] vector: each worker's *own* depth excess caps how many VWs
  it may shed this slot (``plan_pairs``/``rebalance_step`` consume the
  vector as per-worker shed caps), so one flooded worker no longer
  opens the budget for every mildly-backed-up one.
* **Busy/idle hysteresis** (``hysteresis=True``). Signals latch:
  a worker *enters* the busy set only after its pressure has exceeded
  the enter level for ``dwell`` consecutive slots, and *exits* only
  when pressure falls below a separate, lower exit level (and
  symmetrically for idle). Near the granularity boundary the raw
  signal flips every slot; the latched signal does not.

``controller_step`` is jit-able alongside ``rebalance_step`` — all
state lives in a ``ControllerState`` of device arrays, and the flap
counter (latched-signal transitions) is the telemetry the Fig-12
flap benchmark consumes. With both features off the emitted masks are
exactly the raw threshold comparisons and the budget equals
``max_moves``, so the delegation engine's behaviour is bit-identical
to the static configuration (CI-gated).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ControllerConfig(NamedTuple):
    n_workers: int
    # --- adaptive move budget ---
    adaptive_moves: bool = False   # derive the budget from queue depth
    min_moves: int = 1             # budget floor at equilibrium
    max_moves: int = 8             # = the engine's max_moves_per_slot
    depth_decay: float = 0.5       # EWMA decay of per-worker depths;
                                   # window ≈ 1/(1-decay) slots
    per_worker_budget: bool = False  # emit an [n] budget vector (each
                                   # worker's own EWMA'd depth excess)
                                   # instead of one fleet-wide scalar;
                                   # delegation caps each worker's shed
                                   # count by its entry
    # --- busy/idle hysteresis ---
    hysteresis: bool = False       # latch signals between enter/exit
    dwell: int = 3                 # consecutive over-enter slots before
                                   # a new signal latches
    # --- migration-cost cap ---
    byte_budget: float = 0.0       # max VW state bytes one slot may
                                   # migrate (0 = unmetered); divided by
                                   # the caller's ``unit_bytes`` (bytes
                                   # one move transfers) to cap the
                                   # emitted move budget


class ControllerState(NamedTuple):
    depth_ewma: jnp.ndarray   # [n] f32 EWMA'd queue depth / backlog
    busy_latch: jnp.ndarray   # [n] bool signals emitted last slot
    idle_latch: jnp.ndarray   # [n] bool
    busy_dwell: jnp.ndarray   # [n] i32 consecutive slots above enter
    idle_dwell: jnp.ndarray   # [n] i32 consecutive slots below enter
    flaps: jnp.ndarray        # []  i32 cumulative emitted-signal flips
    budget: jnp.ndarray       # []  i32 budget emitted last slot


def init_controller(cfg: ControllerConfig) -> ControllerState:
    n = cfg.n_workers
    return ControllerState(
        depth_ewma=jnp.zeros((n,), jnp.float32),
        busy_latch=jnp.zeros((n,), bool),
        idle_latch=jnp.zeros((n,), bool),
        busy_dwell=jnp.zeros((n,), jnp.int32),
        idle_dwell=jnp.zeros((n,), jnp.int32),
        flaps=jnp.zeros((), jnp.int32),
        budget=jnp.full((), cfg.max_moves, jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg",))
def controller_step(cfg: ControllerConfig, state: ControllerState,
                    pressure, depths, unit,
                    enter_busy, exit_busy, enter_idle, exit_idle,
                    unit_bytes=None):
    """One monitoring-slot tick of the controller.

    Args:
      pressure: [n] f32 signal the thresholds compare against (slot
        utilization in the simulator, queue occupancy in serve,
        step-time ratio in the straggler balancer).
      depths: [n] f32 queue depth / backlog per worker, any unit.
      unit: scalar — the backlog one executed move drains per slot
        (typically mean per-VW arrivals per slot); sets the scale of
        the adaptive budget.
      enter_busy/exit_busy: scalars, exit_busy <= enter_busy. A worker
        turns busy above enter_busy (after ``dwell`` slots) and stays
        busy until pressure falls below exit_busy.
      enter_idle/exit_idle: scalars, exit_idle >= enter_idle,
        symmetrically.
      unit_bytes: optional f32 scalar — the state bytes one move
        migrates (e.g. the mean per-VW state size). With
        ``cfg.byte_budget > 0`` the emitted budget is additionally
        capped at ``byte_budget / unit_bytes`` (floored, but never
        below 1 so a starved budget cannot wedge the engine); None or
        ``byte_budget=0`` leaves the budget purely move-count-driven.

    Returns ``(new_state, busy [n] bool, idle [n] bool, budget)``;
    ``budget`` is a scalar i32 — or an [n] i32 vector of per-worker
    shed caps under ``cfg.per_worker_budget``. Feed
    ``busy``/``idle``/``budget`` straight into
    ``delegation.rebalance_step`` (both shapes are accepted).
    """
    pressure = jnp.asarray(pressure, jnp.float32)
    depths = jnp.asarray(depths, jnp.float32)
    raw_busy = pressure > enter_busy
    raw_idle = pressure < enter_idle

    busy_dwell = jnp.where(raw_busy, state.busy_dwell + 1, 0)
    idle_dwell = jnp.where(raw_idle, state.idle_dwell + 1, 0)
    if cfg.hysteresis:
        busy = jnp.where(state.busy_latch, pressure > exit_busy,
                         busy_dwell >= cfg.dwell)
        idle = jnp.where(state.idle_latch, pressure < exit_idle,
                         idle_dwell >= cfg.dwell)
        idle = idle & ~busy       # shedding wins if both ever latch
    else:
        busy, idle = raw_busy, raw_idle

    flips = (jnp.sum(busy != state.busy_latch)
             + jnp.sum(idle != state.idle_latch)).astype(jnp.int32)

    depth_ewma = (cfg.depth_decay * state.depth_ewma
                  + (1.0 - cfg.depth_decay) * depths)
    unit_f = jnp.maximum(jnp.asarray(unit, jnp.float32), 1e-9)
    if cfg.adaptive_moves and cfg.per_worker_budget:
        # per-worker: each worker's own backlog above the fleet mean
        # sets how many VWs *it* may shed this slot. Busy workers keep
        # the min_moves pacing floor (a latched busy signal must be
        # able to make progress); everyone else may sit at 0.
        excess_w = jnp.maximum(depth_ewma - jnp.mean(depth_ewma), 0.0)
        demand_w = jnp.ceil(excess_w / unit_f).astype(jnp.int32)
        budget = jnp.clip(demand_w, 0, cfg.max_moves)
        budget = jnp.where(busy, jnp.maximum(budget, cfg.min_moves),
                           budget)
    elif cfg.adaptive_moves:
        excess = jnp.sum(jnp.maximum(
            depth_ewma - jnp.mean(depth_ewma), 0.0))
        demand = jnp.ceil(excess / unit_f)
        budget = jnp.clip(demand.astype(jnp.int32),
                          cfg.min_moves, cfg.max_moves)
    else:
        budget = jnp.full((), cfg.max_moves, jnp.int32)
    if cfg.byte_budget > 0 and unit_bytes is not None:
        fit = jnp.floor(cfg.byte_budget / jnp.maximum(
            jnp.asarray(unit_bytes, jnp.float32), 1e-9)).astype(jnp.int32)
        budget = jnp.minimum(budget, jnp.maximum(fit, 1))

    new_state = ControllerState(
        depth_ewma=depth_ewma,
        busy_latch=busy,
        idle_latch=idle,
        busy_dwell=busy_dwell,
        idle_dwell=idle_dwell,
        flaps=state.flaps + flips,
        # telemetry stays a scalar either way (the cg scan stacks it):
        # the vector's effective total is what the engine can execute
        budget=(jnp.minimum(jnp.sum(budget), cfg.max_moves)
                .astype(jnp.int32) if budget.ndim else budget))
    return new_state, busy, idle, budget


class DelegationController:
    """Stateful host-side wrapper over ``controller_step`` for callers
    that tick from Python (the serving router, the straggler balancer);
    the CG simulator threads ``ControllerState`` through its scan
    directly. Holds the config, the device-resident state and the
    threshold levels; ``step`` mutates the state in place and returns
    the masks + budget for this slot."""

    def __init__(self, cfg: ControllerConfig, *,
                 enter_busy: float, exit_busy: float,
                 enter_idle: float, exit_idle: float):
        self.cfg = cfg
        self.enter_busy, self.exit_busy = enter_busy, exit_busy
        self.enter_idle, self.exit_idle = enter_idle, exit_idle
        self.state = init_controller(cfg)

    @classmethod
    def from_thresholds(cls, cfg: ControllerConfig, *, theta_busy: float,
                        theta_idle: float, margin: float):
        """The standard enter/exit derivation every consumer uses: busy
        exits ``margin`` below its enter level, idle ``margin`` above."""
        return cls(cfg, enter_busy=theta_busy,
                   exit_busy=theta_busy - margin,
                   enter_idle=theta_idle,
                   exit_idle=theta_idle + margin)

    def step(self, pressure, depths, unit=1.0, unit_bytes=None):
        self.state, busy, idle, budget = controller_step(
            self.cfg, self.state, pressure, depths, unit,
            self.enter_busy, self.exit_busy,
            self.enter_idle, self.exit_idle, unit_bytes)
        return busy, idle, budget

    @property
    def flaps(self) -> int:
        return int(self.state.flaps)

    @property
    def last_budget(self) -> int:
        return int(self.state.budget)
