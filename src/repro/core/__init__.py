"""Paper core: partitioners, Consistent Grouping runtime, simulation."""
from . import cg, hashing, metrics, partitioners, simulation, streams  # noqa: F401

__all__ = ["cg", "hashing", "metrics", "partitioners", "simulation", "streams"]
