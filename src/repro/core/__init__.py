"""Paper core: partitioners, Consistent Grouping runtime, simulation."""
from . import (cg, controller, delegation, hashing, metrics,  # noqa: F401
               partitioners, simulation, streams)

__all__ = ["cg", "controller", "delegation", "hashing", "metrics",
           "partitioners", "simulation", "streams"]
