"""Paper core: partitioners, Consistent Grouping runtime, simulation."""
from . import (cg, delegation, hashing, metrics, partitioners,  # noqa: F401
               simulation, streams)

__all__ = ["cg", "delegation", "hashing", "metrics", "partitioners",
           "simulation", "streams"]
