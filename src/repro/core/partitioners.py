"""The seven stream-partitioning strategies of the paper (Table II).

Every partitioner consumes a key stream (int32 ids) and produces, one
message at a time (``jax.lax.scan`` — exactly the paper's "one message
per unit time" model), the bin index each message is routed to.

Bins are *virtual workers* when driven by ``repro.core.cg`` and physical
workers when used standalone (the paper's Figures 4/7/8 use them
standalone over n_bins = workers × VWs).

Schemes
-------
KG    key grouping                      H(j)                    stateless
SG    shuffle grouping                  round robin             stateless
PKG   partial key grouping              2 key-choices, argmin   load state
PoTC  power of two choices              2 msg-choices, argmin   load state
CH    consistent hashing bounded load   clockwise probe < cap   ring + load
PoRC  power of random choices (Alg. 1)  salted probe < cap      load state
GREEDY_D  Greedy-d (§VI-A-1)            d key-choices, argmin   load state
D-Choices  heavy keys ≤ d_heavy probes, tail keys 2   load + sketch
W-Choices  heavy keys ≤ n probes, tail keys 2         load + sketch

Each load-stateful scheme (PKG/PoTC/PoRC) also has a ``*_blocked``
block-parallel variant routing B messages per load snapshot —
bit-identical to the oracle at B=1, eventually consistent above (the
staleness license of PKG / "The Power of Both Choices"). The PoRC block
engine itself lives in ``repro.kernels`` (Pallas kernel + jnp oracle),
as does the multi-source engine behind
``power_of_random_choices_multisource`` (§V-C: S sources with local
load views, delta-merge synchronized).

D-Choices / W-Choices ("When Two Choices Are not Enough",
arXiv:1510.05714) ride the same block engine with a per-key probe-depth
policy: a count-min sketch classifies each key at the block boundary,
heavy keys get up to ``d_heavy`` (D) or ``n_bins`` (W) probe choices
while the tail keeps ``d_tail=2`` — bounding imbalance *and* key
replication at once. See ``repro.kernels.ref.HHPolicy`` and
``docs/partitioners.md`` for the playbook.

State-carry contract: every partitioner in this module routes the whole
stream it is given against *fresh* state (zero loads, empty sketch) and
discards that state on return — calls never observe each other. For
state that continues across calls (slots, serving), drive the kernel
engines via ``repro.core.cg`` or ``repro.serve`` instead.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import hash_to_bins, hash_u32, hash_unit_interval

# Cap on PoRC/CH probe chains. The analysis (§VI-B) shows a key never
# needs more than ~n probes once eps > 1/(n-1); 4·n is a safe ceiling.
_MAX_PROBES_FACTOR = 4


# ---------------------------------------------------------------------------
# Stateless schemes
# ---------------------------------------------------------------------------

def key_grouping(keys: jnp.ndarray, n_bins: int, salt: int = 1) -> jnp.ndarray:
    """KG: pure hash of the key."""
    return hash_to_bins(keys, salt, n_bins)


def shuffle_grouping(keys: jnp.ndarray, n_bins: int, offset: int = 0) -> jnp.ndarray:
    """SG: cyclic round robin, key-oblivious."""
    m = keys.shape[0]
    return ((jnp.arange(m, dtype=jnp.int32) + offset) % n_bins).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Greedy-d (covers PKG d=2 on keys, PoTC d=2 on message ids)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_bins", "d", "on_message_id"))
def greedy_d(keys: jnp.ndarray, n_bins: int, d: int = 2,
             on_message_id: bool = False) -> jnp.ndarray:
    """Greedy-d balls-and-bins (§VI-A-1): place on argmin-load choice.

    ``on_message_id=False`` hashes the *key* (PKG when d=2: key splitting);
    ``on_message_id=True`` hashes the *message index* (PoTC when d=2 —
    equivalent to fresh random choices per message).
    """
    m = keys.shape[0]
    ids = jnp.arange(m, dtype=jnp.int32) if on_message_id else keys
    salts = jnp.arange(1, d + 1, dtype=jnp.uint32)

    def step(load, x):
        cand = hash_to_bins(x, salts, n_bins)           # (d,)
        pick = cand[jnp.argmin(load[cand])]
        return load.at[pick].add(1), pick

    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.int32), ids)
    return assignment


def partial_key_grouping(keys: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """PKG = Greedy-2 over keys."""
    return greedy_d(keys, n_bins, d=2, on_message_id=False)


def power_of_two_choices(keys: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """PoTC = Greedy-2 over message ids."""
    return greedy_d(keys, n_bins, d=2, on_message_id=True)


# ---------------------------------------------------------------------------
# PoRC — Algorithm 1, exact sequential semantics
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_bins", "eps"))
def power_of_random_choices(keys: jnp.ndarray, n_bins: int,
                            eps: float = 0.01) -> jnp.ndarray:
    """PoRC (Alg. 1): probe H(j+salt), salt=1,2,… until load < (1+eps)·m_t/n.

    m_t counts the arriving message itself so the capacity is strictly
    positive from the first message on. A probe ceiling of 4·n_bins
    guards the (never observed once eps > 1/(n-1)) pathological chain;
    on exhaustion the least-loaded bin is used.
    """
    m = keys.shape[0]
    max_probes = _MAX_PROBES_FACTOR * n_bins

    def step(load, xt):
        key, t = xt
        cap = (1.0 + eps) * (t + 1.0) / n_bins

        def cond(c):
            salt, bin_, probes = c
            return (load[bin_] >= cap) & (probes < max_probes)

        def body(c):
            salt, _, probes = c
            salt = salt + 1
            return salt, hash_to_bins(key, salt, n_bins), probes + 1

        init = (jnp.uint32(1), hash_to_bins(key, jnp.uint32(1), n_bins),
                jnp.int32(0))
        _, bin_, probes = jax.lax.while_loop(cond, body, init)
        bin_ = jnp.where(probes >= max_probes, jnp.argmin(load).astype(jnp.int32), bin_)
        return load.at[bin_].add(1.0), bin_

    t = jnp.arange(m, dtype=jnp.float32)
    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.float32), (keys, t))
    return assignment


# ---------------------------------------------------------------------------
# Block-parallel variants — eventually-consistent load state
# ---------------------------------------------------------------------------
#
# Each block of B messages is routed against the load snapshot taken at
# the block boundary (PKG/"Power of Both Choices" show load-state routing
# tolerates slightly stale estimates). With block=1 every variant is
# bit-identical to its sequential oracle above; with block>1 the routing
# is the block-synchronous semantics of ``repro.kernels``.

@functools.partial(jax.jit, static_argnames=("n_bins", "d", "block"))
def _greedy_blocked_core(ids: jnp.ndarray, load0: jnp.ndarray, n_bins: int,
                         d: int, block: int):
    """Greedy-d over full blocks: every message in a block picks the
    argmin-load candidate against the block-start snapshot."""
    nb = ids.shape[0] // block
    salts = jnp.arange(1, d + 1, dtype=jnp.uint32)
    cand = hash_to_bins(ids[:, None], salts, n_bins).reshape(nb, block, d)

    def blk(load, c):
        pick = c[jnp.arange(c.shape[0]), jnp.argmin(load[c], axis=1)]
        return load.at[pick].add(1), pick

    load, picks = jax.lax.scan(blk, load0, cand)
    return picks.reshape(-1), load


def greedy_d_blocked(keys: jnp.ndarray, n_bins: int, d: int = 2,
                     on_message_id: bool = False,
                     block: int = 128) -> jnp.ndarray:
    """Block-parallel Greedy-d (batched PKG / PoTC). Any stream length;
    a trailing partial block runs as power-of-two sub-blocks (see
    ``repro.kernels.ref.block_spans``)."""
    from repro.kernels.ref import route_in_spans  # deferred: core ← kernels
    m = keys.shape[0]
    ids = (jnp.arange(m, dtype=jnp.int32) if on_message_id
           else keys.astype(jnp.int32))
    assign, _ = route_in_spans(
        ids, block, jnp.zeros(n_bins, jnp.int32),
        lambda sub, blk, load: _greedy_blocked_core(sub, load, n_bins, d, blk))
    return assign


def partial_key_grouping_blocked(keys: jnp.ndarray, n_bins: int,
                                 block: int = 128) -> jnp.ndarray:
    """Batched PKG = block-parallel Greedy-2 over keys."""
    return greedy_d_blocked(keys, n_bins, d=2, on_message_id=False, block=block)


def power_of_two_choices_blocked(keys: jnp.ndarray, n_bins: int,
                                 block: int = 128) -> jnp.ndarray:
    """Batched PoTC = block-parallel Greedy-2 over message ids."""
    return greedy_d_blocked(keys, n_bins, d=2, on_message_id=True, block=block)


def power_of_random_choices_blocked(keys: jnp.ndarray, n_bins: int,
                                    eps: float = 0.01,
                                    block: int = 128,
                                    engine: str = "ref") -> jnp.ndarray:
    """Batched PoRC: Alg. 1 against a per-block load snapshot, capacity
    evaluated at the block boundary. Delegates to the kernel block
    engine (``repro.kernels.ref``), which carries state across blocks.
    ``engine``: "ref" (jnp scan) | "pallas" (Pallas kernel, bit-identical)
    | "auto" (Pallas on TPU, jnp elsewhere)."""
    from repro.kernels import resolve_engine  # deferred: core ← kernels
    from repro.kernels.ref import ref_porc_route
    assign, _ = ref_porc_route(keys, n_bins, block=block, eps=eps,
                               engine=resolve_engine(engine))
    return assign


def power_of_random_choices_multisource(keys: jnp.ndarray, n_bins: int,
                                        n_sources: int, eps: float = 0.01,
                                        block: int = 128,
                                        sync_every: int = 1,
                                        hh=None,
                                        engine: str = "ref") -> jnp.ndarray:
    """Multi-source PoRC (§V-C): the stream splits round-robin across
    ``n_sources`` sources, each routing blocks against its local load
    view (shared merged base + own unpublished delta); views synchronize
    by delta-merge every ``sync_every`` blocks. ``n_sources=1,
    sync_every=1`` is bit-identical to the blocked single-source path.
    ``hh`` (an ``HHPolicy``) turns on heavy-hitter-aware probe depths;
    the per-source sketch deltas merge on the same sync cadence.
    ``engine`` selects the block engine ("ref" | "pallas" | "auto")."""
    from repro.kernels import resolve_engine  # deferred: core ← kernels
    from repro.kernels.ref import ref_porc_multisource
    assign, _ = ref_porc_multisource(keys, n_bins, n_sources,
                                     sync_every=sync_every, block=block,
                                     eps=eps, policy=hh,
                                     engine=resolve_engine(engine))
    return assign


# ---------------------------------------------------------------------------
# D-Choices / W-Choices — heavy-hitter-aware probe depths (1510.05714)
# ---------------------------------------------------------------------------

def _hh_choices(keys: jnp.ndarray, n_bins: int, scheme: str, eps: float,
                block: int, hh, engine: str = "ref") -> jnp.ndarray:
    from repro.kernels import resolve_engine  # deferred: core ← kernels
    from repro.kernels.ref import HHPolicy, ref_porc_route
    policy = HHPolicy(scheme=scheme) if hh is None else hh._replace(scheme=scheme)
    assign, _ = ref_porc_route(keys, n_bins, block=block, eps=eps,
                               policy=policy, engine=resolve_engine(engine))
    return assign


def d_choices(keys: jnp.ndarray, n_bins: int, eps: float = 0.01,
              block: int = 128, hh=None,
              engine: str = "ref") -> jnp.ndarray:
    """D-Choices: PoRC block engine with per-key probe budgets — heavy
    keys (count-min estimate ≥ ``hot_fraction``·m_t) probe up to
    ``d_heavy`` salted choices, tail keys keep ``d_tail=2``. Caps the
    replication of *every* key at d_heavy; imbalance degrades once the
    hottest key's balanced spread ceil(p₁·n/(1+eps)) exceeds d_heavy —
    prefer W-Choices past that point (see docs/partitioners.md).
    ``hh`` overrides the default ``HHPolicy`` knobs (scheme is forced)."""
    return _hh_choices(keys, n_bins, "d", eps, block, hh, engine)


def w_choices(keys: jnp.ndarray, n_bins: int, eps: float = 0.01,
              block: int = 128, hh=None,
              engine: str = "ref") -> jnp.ndarray:
    """W-Choices: like D-Choices but a heavy key's probe ceiling is the
    full worker set, with the budget still set per key by the Eq.-2
    schedule ceil(headroom·p̂·n/(1+eps)) — tail replication stays at
    d_tail while the few heavy keys spread just wide enough to balance.
    ``hh`` overrides the default ``HHPolicy`` knobs (scheme is forced)."""
    return _hh_choices(keys, n_bins, "w", eps, block, hh, engine)


# ---------------------------------------------------------------------------
# CH — consistent hashing with bounded loads (Mirrokni et al.)
# ---------------------------------------------------------------------------

class _Ring(NamedTuple):
    order: jnp.ndarray      # bin ids sorted by ring position
    positions: jnp.ndarray  # sorted ring positions


def build_ring(n_bins: int, points_per_bin: int = 1, salt0: int = 7) -> _Ring:
    """Hash each bin onto the unit circle (points_per_bin replicas)."""
    bins = jnp.arange(n_bins, dtype=jnp.int32)
    salts = jnp.arange(salt0, salt0 + points_per_bin, dtype=jnp.uint32)
    pos = hash_unit_interval(bins[:, None], salts).reshape(-1)
    owners = jnp.tile(bins[:, None], (1, points_per_bin)).reshape(-1)
    idx = jnp.argsort(pos)
    return _Ring(order=owners[idx], positions=pos[idx])


@functools.partial(jax.jit, static_argnames=("n_bins", "eps", "points_per_bin"))
def consistent_hashing_bounded(keys: jnp.ndarray, n_bins: int,
                               eps: float = 0.01,
                               points_per_bin: int = 1) -> jnp.ndarray:
    """CH: walk clockwise from H(key)'s successor to first bin with
    load < (1+eps)·m_t/n (Consistent Hashing with Bounded Loads)."""
    ring = build_ring(n_bins, points_per_bin)
    n_points = ring.order.shape[0]
    m = keys.shape[0]
    max_probes = _MAX_PROBES_FACTOR * n_points

    def step(load, xt):
        key, t = xt
        cap = (1.0 + eps) * (t + 1.0) / n_bins
        p = hash_unit_interval(key, jnp.uint32(1))
        start = jnp.searchsorted(ring.positions, p) % n_points

        def cond(c):
            i, probes = c
            return (load[ring.order[i]] >= cap) & (probes < max_probes)

        def body(c):
            i, probes = c
            return (i + 1) % n_points, probes + 1

        i, probes = jax.lax.while_loop(cond, body, (start.astype(jnp.int32),
                                                    jnp.int32(0)))
        bin_ = jnp.where(probes >= max_probes,
                         jnp.argmin(load).astype(jnp.int32), ring.order[i])
        return load.at[bin_].add(1.0), bin_

    t = jnp.arange(m, dtype=jnp.float32)
    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.float32), (keys, t))
    return assignment


# ---------------------------------------------------------------------------
# Registry used by benchmarks and the CG runtime
# ---------------------------------------------------------------------------

def route(scheme: str, keys: jnp.ndarray, n_bins: int, *,
          eps: float = 0.01, block_size: int | None = None,
          sources: int = 1, sync_every: int = 1, hh=None,
          engine: str = "ref") -> jnp.ndarray:
    """Route a full stream with the named scheme (paper Table II symbols).

    ``block_size=None`` uses the exact sequential oracles (one message
    per unit time). Any ``block_size >= 1`` takes the block-parallel
    fast path for the load-stateful schemes (PKG/PoTC/PoRC) —
    bit-identical at block_size=1, eventually consistent above. KG/SG
    are stateless (already fully parallel); CH walks a ring sequentially
    and has no blocked variant, so both ignore ``block_size``.

    ``sources > 1`` models the paper's §V-C distributed sources for
    PoRC: the stream splits round-robin across that many sources, each
    with a local load view synchronized every ``sync_every`` blocks
    (requires the block path; KG/SG are source-oblivious and the other
    load-stateful schemes have no multi-source variant — they reject
    ``sources > 1``).

    ``DCHOICES`` / ``WCHOICES`` are block-native (the sketch classifies
    keys at block boundaries — there is no sequential oracle), so
    ``block_size=None`` means the default block of 128; both accept
    ``sources > 1``. ``hh`` (a ``kernels.ref.HHPolicy``) overrides the
    sketch/budget knobs for them and is rejected for every other scheme.

    ``engine`` selects the block-engine implementation for the PoRC
    family (PORC blocked/multisource and DCHOICES/WCHOICES): ``"ref"``
    (the jnp scan — the default), ``"pallas"`` (the Pallas kernel,
    bit-identical: load/delta/sketch lanes in VMEM scratch, compiled on
    TPU and interpreted elsewhere), or ``"auto"`` (Pallas on TPU, jnp
    elsewhere). The sequential oracles and the non-PoRC schemes have no
    kernel variant and reject a non-"ref" engine.
    """
    scheme = scheme.upper()
    if sources > 1 and scheme not in ("PORC", "KG", "SG") + HH_SCHEMES:
        raise ValueError(f"scheme {scheme!r} has no multi-source variant")
    if hh is not None and scheme not in HH_SCHEMES:
        raise ValueError(f"scheme {scheme!r} takes no heavy-hitter policy")
    if engine != "ref" and scheme not in ("PORC",) + HH_SCHEMES:
        raise ValueError(f"scheme {scheme!r} has no kernel engine variant")
    if engine != "ref" and scheme == "PORC" and not (block_size or sources > 1):
        raise ValueError("engine applies to the block path — pass "
                         "block_size (the sequential oracle is jnp-only)")
    if scheme in HH_SCHEMES:
        from repro.kernels.ref import HHPolicy  # deferred: core ← kernels
        letter = "d" if scheme == "DCHOICES" else "w"
        if sources > 1:
            policy = (HHPolicy(scheme=letter) if hh is None
                      else hh._replace(scheme=letter))
            return power_of_random_choices_multisource(
                keys, n_bins, sources, eps=eps, block=block_size or 128,
                sync_every=sync_every, hh=policy, engine=engine)
        return _hh_choices(keys, n_bins, letter, eps, block_size or 128, hh,
                           engine)
    if scheme == "KG":
        return key_grouping(keys, n_bins)
    if scheme == "SG":
        return shuffle_grouping(keys, n_bins)
    if scheme == "PKG":
        if block_size:
            return partial_key_grouping_blocked(keys, n_bins, block=block_size)
        return partial_key_grouping(keys, n_bins)
    if scheme == "POTC":
        if block_size:
            return power_of_two_choices_blocked(keys, n_bins, block=block_size)
        return power_of_two_choices(keys, n_bins)
    if scheme == "PORC":
        if sources > 1:
            return power_of_random_choices_multisource(
                keys, n_bins, sources, eps=eps, block=block_size or 128,
                sync_every=sync_every, engine=engine)
        if block_size:
            return power_of_random_choices_blocked(keys, n_bins, eps=eps,
                                                   block=block_size,
                                                   engine=engine)
        return power_of_random_choices(keys, n_bins, eps=eps)
    if scheme == "CH":
        return consistent_hashing_bounded(keys, n_bins, eps=eps)
    raise ValueError(f"unknown scheme {scheme!r}")


ALL_SCHEMES = ("KG", "SG", "PKG", "POTC", "CH", "PORC")
BLOCKED_SCHEMES = ("PKG", "POTC", "PORC")
HH_SCHEMES = ("DCHOICES", "WCHOICES")
