"""The seven stream-partitioning strategies of the paper (Table II).

Every partitioner consumes a key stream (int32 ids) and produces, one
message at a time (``jax.lax.scan`` — exactly the paper's "one message
per unit time" model), the bin index each message is routed to.

Bins are *virtual workers* when driven by ``repro.core.cg`` and physical
workers when used standalone (the paper's Figures 4/7/8 use them
standalone over n_bins = workers × VWs).

Schemes
-------
KG    key grouping                      H(j)                    stateless
SG    shuffle grouping                  round robin             stateless
PKG   partial key grouping              2 key-choices, argmin   load state
PoTC  power of two choices              2 msg-choices, argmin   load state
CH    consistent hashing bounded load   clockwise probe < cap   ring + load
PoRC  power of random choices (Alg. 1)  salted probe < cap      load state
GREEDY_D  Greedy-d (§VI-A-1)            d key-choices, argmin   load state

The batch-parallel (eventually-consistent) PoRC lives in
``repro.kernels`` — this module is the exact sequential oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import hash_to_bins, hash_u32, hash_unit_interval

# Cap on PoRC/CH probe chains. The analysis (§VI-B) shows a key never
# needs more than ~n probes once eps > 1/(n-1); 4·n is a safe ceiling.
_MAX_PROBES_FACTOR = 4


# ---------------------------------------------------------------------------
# Stateless schemes
# ---------------------------------------------------------------------------

def key_grouping(keys: jnp.ndarray, n_bins: int, salt: int = 1) -> jnp.ndarray:
    """KG: pure hash of the key."""
    return hash_to_bins(keys, salt, n_bins)


def shuffle_grouping(keys: jnp.ndarray, n_bins: int, offset: int = 0) -> jnp.ndarray:
    """SG: cyclic round robin, key-oblivious."""
    m = keys.shape[0]
    return ((jnp.arange(m, dtype=jnp.int32) + offset) % n_bins).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Greedy-d (covers PKG d=2 on keys, PoTC d=2 on message ids)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_bins", "d", "on_message_id"))
def greedy_d(keys: jnp.ndarray, n_bins: int, d: int = 2,
             on_message_id: bool = False) -> jnp.ndarray:
    """Greedy-d balls-and-bins (§VI-A-1): place on argmin-load choice.

    ``on_message_id=False`` hashes the *key* (PKG when d=2: key splitting);
    ``on_message_id=True`` hashes the *message index* (PoTC when d=2 —
    equivalent to fresh random choices per message).
    """
    m = keys.shape[0]
    ids = jnp.arange(m, dtype=jnp.int32) if on_message_id else keys
    salts = jnp.arange(1, d + 1, dtype=jnp.uint32)

    def step(load, x):
        cand = hash_to_bins(x, salts, n_bins)           # (d,)
        pick = cand[jnp.argmin(load[cand])]
        return load.at[pick].add(1), pick

    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.int32), ids)
    return assignment


def partial_key_grouping(keys: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """PKG = Greedy-2 over keys."""
    return greedy_d(keys, n_bins, d=2, on_message_id=False)


def power_of_two_choices(keys: jnp.ndarray, n_bins: int) -> jnp.ndarray:
    """PoTC = Greedy-2 over message ids."""
    return greedy_d(keys, n_bins, d=2, on_message_id=True)


# ---------------------------------------------------------------------------
# PoRC — Algorithm 1, exact sequential semantics
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_bins", "eps"))
def power_of_random_choices(keys: jnp.ndarray, n_bins: int,
                            eps: float = 0.01) -> jnp.ndarray:
    """PoRC (Alg. 1): probe H(j+salt), salt=1,2,… until load < (1+eps)·m_t/n.

    m_t counts the arriving message itself so the capacity is strictly
    positive from the first message on. A probe ceiling of 4·n_bins
    guards the (never observed once eps > 1/(n-1)) pathological chain;
    on exhaustion the least-loaded bin is used.
    """
    m = keys.shape[0]
    max_probes = _MAX_PROBES_FACTOR * n_bins

    def step(load, xt):
        key, t = xt
        cap = (1.0 + eps) * (t + 1.0) / n_bins

        def cond(c):
            salt, bin_, probes = c
            return (load[bin_] >= cap) & (probes < max_probes)

        def body(c):
            salt, _, probes = c
            salt = salt + 1
            return salt, hash_to_bins(key, salt, n_bins), probes + 1

        init = (jnp.uint32(1), hash_to_bins(key, jnp.uint32(1), n_bins),
                jnp.int32(0))
        _, bin_, probes = jax.lax.while_loop(cond, body, init)
        bin_ = jnp.where(probes >= max_probes, jnp.argmin(load).astype(jnp.int32), bin_)
        return load.at[bin_].add(1.0), bin_

    t = jnp.arange(m, dtype=jnp.float32)
    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.float32), (keys, t))
    return assignment


# ---------------------------------------------------------------------------
# CH — consistent hashing with bounded loads (Mirrokni et al.)
# ---------------------------------------------------------------------------

class _Ring(NamedTuple):
    order: jnp.ndarray      # bin ids sorted by ring position
    positions: jnp.ndarray  # sorted ring positions


def build_ring(n_bins: int, points_per_bin: int = 1, salt0: int = 7) -> _Ring:
    """Hash each bin onto the unit circle (points_per_bin replicas)."""
    bins = jnp.arange(n_bins, dtype=jnp.int32)
    salts = jnp.arange(salt0, salt0 + points_per_bin, dtype=jnp.uint32)
    pos = hash_unit_interval(bins[:, None], salts).reshape(-1)
    owners = jnp.tile(bins[:, None], (1, points_per_bin)).reshape(-1)
    idx = jnp.argsort(pos)
    return _Ring(order=owners[idx], positions=pos[idx])


@functools.partial(jax.jit, static_argnames=("n_bins", "eps", "points_per_bin"))
def consistent_hashing_bounded(keys: jnp.ndarray, n_bins: int,
                               eps: float = 0.01,
                               points_per_bin: int = 1) -> jnp.ndarray:
    """CH: walk clockwise from H(key)'s successor to first bin with
    load < (1+eps)·m_t/n (Consistent Hashing with Bounded Loads)."""
    ring = build_ring(n_bins, points_per_bin)
    n_points = ring.order.shape[0]
    m = keys.shape[0]
    max_probes = _MAX_PROBES_FACTOR * n_points

    def step(load, xt):
        key, t = xt
        cap = (1.0 + eps) * (t + 1.0) / n_bins
        p = hash_unit_interval(key, jnp.uint32(1))
        start = jnp.searchsorted(ring.positions, p) % n_points

        def cond(c):
            i, probes = c
            return (load[ring.order[i]] >= cap) & (probes < max_probes)

        def body(c):
            i, probes = c
            return (i + 1) % n_points, probes + 1

        i, probes = jax.lax.while_loop(cond, body, (start.astype(jnp.int32),
                                                    jnp.int32(0)))
        bin_ = jnp.where(probes >= max_probes,
                         jnp.argmin(load).astype(jnp.int32), ring.order[i])
        return load.at[bin_].add(1.0), bin_

    t = jnp.arange(m, dtype=jnp.float32)
    _, assignment = jax.lax.scan(step, jnp.zeros(n_bins, jnp.float32), (keys, t))
    return assignment


# ---------------------------------------------------------------------------
# Registry used by benchmarks and the CG runtime
# ---------------------------------------------------------------------------

def route(scheme: str, keys: jnp.ndarray, n_bins: int, *,
          eps: float = 0.01) -> jnp.ndarray:
    """Route a full stream with the named scheme (paper Table II symbols)."""
    scheme = scheme.upper()
    if scheme == "KG":
        return key_grouping(keys, n_bins)
    if scheme == "SG":
        return shuffle_grouping(keys, n_bins)
    if scheme == "PKG":
        return partial_key_grouping(keys, n_bins)
    if scheme == "POTC":
        return power_of_two_choices(keys, n_bins)
    if scheme == "PORC":
        return power_of_random_choices(keys, n_bins, eps=eps)
    if scheme == "CH":
        return consistent_hashing_bounded(keys, n_bins, eps=eps)
    raise ValueError(f"unknown scheme {scheme!r}")


ALL_SCHEMES = ("KG", "SG", "PKG", "POTC", "CH", "PORC")
