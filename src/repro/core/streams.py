"""Stream generators reproducing the paper's workloads (Table I).

* Zipf(z) over ``n_keys`` unique keys, z in [0.1, 2.0] (the ZF dataset).
* WP-like / TW-like traces: same (p1, #keys) skew profile as Table I at a
  reduced message count, plus the diurnal rate modulation of Fig. 5.
* Heterogeneity profiles: "y machines are z times more powerful" (Q2/Q3),
  including the dynamic schedule of Fig. 13.

Keys are int32 ids sorted by decreasing frequency (rank 0 = hottest), so
``p_of_rank`` doubles as the ground-truth arrival-rate vector used in the
memory-footprint bounds (Eqs. 1–2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def zipf_probs(n_keys: int, z: float) -> np.ndarray:
    """Probability mass of the zipf(z) distribution over ranks 1..n_keys."""
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = ranks ** (-z)
    return (w / w.sum()).astype(np.float64)


def sample_zipf_stream(key: jax.Array, n_messages: int, n_keys: int,
                       z: float) -> jnp.ndarray:
    """i.i.d. zipf(z) key stream as int32 ranks (0 = most frequent)."""
    p = jnp.asarray(zipf_probs(n_keys, z), dtype=jnp.float32)
    return jax.random.choice(key, n_keys, shape=(n_messages,), p=p).astype(jnp.int32)


@dataclass(frozen=True)
class TraceSpec:
    """Reduced-scale analogue of a Table I dataset."""
    name: str
    n_messages: int
    n_keys: int
    p1: float          # mass of the most frequent key
    z_tail: float      # zipf exponent of the tail
    diurnal: bool      # Fig. 5 style rate modulation


# Table I: WP 22M msgs / 2.9M keys / p1 = 9.32%; TW 1.2G / 31M / 2.67%.
# Reduced 20x-ish in messages, keys scaled to keep keys-per-message ratio.
WP_TRACE = TraceSpec("WP", n_messages=1_000_000, n_keys=130_000, p1=0.0932,
                     z_tail=1.0, diurnal=True)
TW_TRACE = TraceSpec("TW", n_messages=2_000_000, n_keys=500_000, p1=0.0267,
                     z_tail=0.8, diurnal=True)


def trace_probs(spec: TraceSpec) -> np.ndarray:
    """Zipf tail re-weighted so the top key carries exactly spec.p1."""
    p = zipf_probs(spec.n_keys, spec.z_tail)
    p1 = spec.p1
    tail = p[1:] * (1.0 - p1) / p[1:].sum()
    return np.concatenate([[p1], tail])


def sample_trace(key: jax.Array, spec: TraceSpec,
                 n_messages: int | None = None) -> jnp.ndarray:
    m = n_messages or spec.n_messages
    p = jnp.asarray(trace_probs(spec), dtype=jnp.float32)
    return jax.random.choice(key, spec.n_keys, shape=(m,), p=p).astype(jnp.int32)


def diurnal_rate(t_hours: np.ndarray, base: float = 1.0,
                 amplitude: float = 0.35) -> np.ndarray:
    """Fig. 5-style messages-per-hour modulation (one diurnal cycle)."""
    return base * (1.0 + amplitude * np.sin(2 * np.pi * t_hours / 24.0))


# ---------------------------------------------------------------------------
# Heterogeneity profiles (paper Q2/Q3)
# ---------------------------------------------------------------------------

def heterogeneous_capacities(n: int, y: int, zfac: float,
                             normalize: bool = True) -> np.ndarray:
    """y of n machines are zfac times more powerful than the rest.

    Normalized so capacities sum to 1 (paper §VI convention).
    """
    c = np.ones(n, dtype=np.float64)
    c[:y] = zfac
    if normalize:
        c /= c.sum()
    return c


def dynamic_capacity_schedule(n: int, total_messages: int) -> list[tuple[int, np.ndarray]]:
    """Fig. 13 schedule: (y,z) = (3,5) -> after 6M msgs (5,4) -> after 12M (2,10).

    Scaled to ``total_messages`` (change points at 1/3 and 2/3). Returns
    [(start_message_index, capacities)], capacities always summing to 1.
    """
    points = [
        (0, heterogeneous_capacities(n, 3, 5.0)),
        (total_messages // 3, heterogeneous_capacities(n, 5, 4.0)),
        (2 * total_messages // 3, heterogeneous_capacities(n, 2, 10.0)),
    ]
    return points
