"""Sharding rules: param pytree → PartitionSpecs, activation constraints.

Strategy (DESIGN.md §5): 2-D param sharding — FSDP over the in-pod
``data`` axis × tensor/expert parallel over ``model``; batch over
(``pod``, ``data``); MoE experts over ``model`` (EP=TP axis). Dims are
sharded only when divisible (helper falls back to replication), so every
(arch × shape × mesh) cell lowers without padding surprises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _divisible(dim: int, mesh, axes) -> bool:
    if axes is None:
        return True
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def spec_if_divisible(shape, mesh, wanted) -> P:
    """Build a PartitionSpec keeping only divisible dims sharded."""
    out = []
    for dim, axes in zip(shape, wanted):
        out.append(axes if _divisible(dim, mesh, axes) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

_MATRIX_RULES: dict[str, tuple] = {
    # name: wanted spec per trailing-dims (without the stacked-layer dim)
    "embed": ("model", "data"),
    "vision_proj": (None, "data"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w1": ("data", "model"),
    "w3": ("data", "model"),
    "w2": ("model", "data"),
    "router": (None, None),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_w": (None, "model"),
}

_EXPERT_RULES = {
    # MoE stacked experts: E on model (EP), in-dim on data (FSDP)
    "w1": ("model", "data", None),
    "w3": ("model", "data", None),
    "w2": ("model", None, "data"),
}


def _rule_for(path: tuple, leaf) -> tuple | None:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    name = names[-1]
    stacked = sum(1 for n in names if n in
                  ("layers", "enc_layers", "dec_layers"))
    in_moe = "moe" in names and "shared" not in names
    nd = leaf.ndim
    if in_moe and name in _EXPERT_RULES and nd >= 3:
        want = _EXPERT_RULES[name]
        pad = nd - len(want)
        return (None,) * pad + want
    if name in _MATRIX_RULES:
        want = _MATRIX_RULES[name]
        if nd < len(want):
            return None
        pad = nd - len(want)
        return (None,) * pad + want
    return None      # norms, biases, scalars → replicated


def param_specs_tree(param_tree, mesh, mode: str = "train"):
    """Map a param pytree (arrays or ShapeDtypeStructs) → PartitionSpecs.

    mode="train": 2-D FSDP("data") × TP("model").
    mode="infer": TP("model") only — weights stay resident (no per-step
    FSDP all-gather; decode is weight-bandwidth-bound, so moving weights
    over ICI at 50 GB/s instead of reading HBM at 819 GB/s is a 16×
    loss). MoE expert stacks keep their EP sharding in both modes.
    mode="replicate": pure data parallel — everything replicated.
    """
    def one(path, leaf):
        if mode == "replicate":
            return P()
        want = _rule_for(path, leaf)
        if want is None:
            return P()
        if mode == "infer":
            names = [getattr(p, "key", getattr(p, "name", None))
                     for p in path]
            if not ("moe" in names and "shared" not in names
                    and leaf.ndim >= 3):
                want = tuple(None if w == "data" else w for w in want)
        return spec_if_divisible(leaf.shape, mesh, want)

    return jax.tree_util.tree_map_with_path(one, param_tree)


def param_shardings(param_tree, mesh, mode: str = "train"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs_tree(param_tree, mesh, mode))


def infer_mode_fits(n_params_total: int, mesh,
                    budget_bytes: float = 8e9) -> bool:
    """Would TP-only (replicated over data) bf16 weights fit per chip?"""
    return 2.0 * n_params_total / mesh.shape["model"] <= budget_bytes


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(batch_tree, mesh, pure_dp: bool = False):
    """Tokens/frames/patches: batch dim over (pod, data)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if pure_dp:
        dp = dp + ("model",)

    def one(leaf):
        want = [dp] + [None] * (leaf.ndim - 1)
        return spec_if_divisible(leaf.shape, mesh, want)

    return jax.tree.map(one, batch_tree)


def cache_specs(cache_tree, mesh, pure_dp: bool = False):
    """KV caches: [L, B, S, KV, Dh] → batch on data, seq on model.
    SSM states: [L, B, H, P, N] → batch on data, heads on model.
    Conv states: [L, B, w, C] → batch on data, channels on model.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if pure_dp:
        dp = dp + ("model",)
        def one_dp(path, leaf):
            if leaf.ndim == 0:
                return P()
            names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
            axis = 1 if names[-1] in ("k", "v", "cross_k", "cross_v", "h",
                                      "conv", "global_k", "global_v",
                                      "local_k", "local_v") else 0
            want = [None] * leaf.ndim
            want[axis] = dp
            return spec_if_divisible(leaf.shape, mesh, want)
        return jax.tree_util.tree_map_with_path(one_dp, cache_tree)

    def one(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = names[-1] if names else None
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "cross_k", "cross_v", "global_k", "global_v",
                    "local_k", "local_v"):
            # [..., B, S, KV, Dh]
            want = [None] * (leaf.ndim - 4) + [dp, "model", None, None]
            return spec_if_divisible(leaf.shape, mesh, want)
        if name == "h":       # [..., B, H, P, N]
            want = [None] * (leaf.ndim - 4) + [dp, "model", None, None]
            return spec_if_divisible(leaf.shape, mesh, want)
        if name == "conv":    # [..., B, w, C]
            want = [None] * (leaf.ndim - 3) + [dp, None, "model"]
            return spec_if_divisible(leaf.shape, mesh, want)
        want = [None] * leaf.ndim
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# Activation constraint rules (installed via models.layers.set_act_sharding)
# ---------------------------------------------------------------------------

def act_rules(mesh, pure_dp: bool = False) -> dict:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if pure_dp:
        # batch over every axis; no tensor/seq parallelism anywhere
        dp = dp + ("model",)
        return {
            "btd": P(dp, None, None),
            "btf": P(dp, None, None),
            "bshd": P(dp, None, None, None),
            "bskd": P(dp, None, None, None),
            "bcv": P(dp, None, None),
            "becd": P(dp, None, None, None),
            "vd": P(),
            "bv": P(dp, None),
            "bhpn": P(dp, None, None, None),
        }
    return {
        # sequence parallelism: the inter-layer residual stream (and the
        # saved remat carries with it) shard over ("data", seq×"model")
        "btd": P(dp, "model", None),
        "btf": P(dp, None, "model"),
        "bshd": P(dp, None, "model", None),
        "bskd": P(dp, None, None, None),
        "bcv": P(dp, None, "model"),
        "becd": P(dp, "model", None, None),
        "vd": P("model", "data"),     # embedding table (+ its gradient)
        "bv": P(dp, "model"),          # decode-step logits
        "bhpn": P(dp, "model", None, None),   # SSD chunk-scan state carry
    }


def scalar_sharding(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serving-runtime routing state (repro.kernels.mesh source lanes)
# ---------------------------------------------------------------------------

# MultiSourcePorcState field -> spec on a ("sources",) mesh: the
# per-source lanes shard row-wise over the axis, everything merged or
# scalar replicates. (Sketch lanes are listed for completeness; the
# mesh engine currently rejects policy-carrying state.)
_ROUTING_LANE_SPECS = {
    "delta": P("sources", None),
    "sketch_delta": P("sources", None, None),
}


def routing_state_specs(state) -> dict:
    """PartitionSpec per ``MultiSourcePorcState`` field for a mesh with
    a ``sources`` axis — lanes sharded, merged views replicated."""
    return {f: _ROUTING_LANE_SPECS.get(f, P())
            for f in type(state)._fields}


def routing_state_shardings(state, mesh):
    """NamedSharding pytree matching ``state`` (None fields stay None)."""
    specs = routing_state_specs(state)
    return type(state)(**{
        f: (None if getattr(state, f) is None
            else NamedSharding(mesh, specs[f]))
        for f in type(state)._fields})
