"""Launchers: mesh construction, dry-run, train and serve drivers.

Deliberately empty of imports: ``dryrun`` must own first-import of jax
(it sets --xla_force_host_platform_device_count before jax initializes,
and ``python -m repro.launch.dryrun`` executes this package __init__
first). Import submodules explicitly: ``from repro.launch import mesh``.
"""
