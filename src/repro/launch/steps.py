"""jit-able train/serve steps with full sharding annotations.

Used by both the real launcher (train.py / serve.py) and the dry-run
(lower + compile only). All shardings are NamedShardings derived from
``sharding.py`` rules; the model code is mesh-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model_zoo as zoo
from repro.models.layers import set_act_sharding

from . import sharding as shd


def install_act_rules(mesh, pure_dp: bool = False):
    rules = shd.act_rules(mesh, pure_dp=pure_dp)
    rules["_mesh"] = mesh
    set_act_sharding(rules)


def make_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    cfg.grad_accum > 1 microbatches the global batch with a scan,
    accumulating f32 grads — live activation memory scales ~1/k at the
    cost of one extra f32 grad buffer (§Perf memory iteration)."""
    k = max(1, cfg.grad_accum)

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, mm), grads = jax.value_and_grad(
                lambda p: zoo.loss_and_metrics(p, cfg, batch),
                has_aux=True)(params)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

            def mb(carry, b):
                gsum, lsum, msum = carry
                (l, m), g = jax.value_and_grad(
                    lambda p: zoo.loss_and_metrics(p, cfg, b),
                    has_aux=True)(params)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                # max_load_frac is a worst-case; everything else averages
                msum = {key: (jnp.maximum(msum[key], m[key])
                              if key == "moe_max_load_frac"
                              else msum[key] + m[key]) for key in msum}
                return (gsum, lsum + l, msum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, msum), _ = jax.lax.scan(
                mb, (g0, jnp.float32(0), zoo.metric_zeros(cfg)), micro)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            mm = {key: (v if key == "moe_max_load_frac" else v / k)
                  for key, v in msum.items()}
        params, opt_state, om = optim.update(params, grads, opt_state,
                                             opt_cfg)
        return params, opt_state, {"loss": loss, **om, **mm}

    return train_step


def make_serve_step(cfg: ModelConfig):
    """(params, cache, tokens) → (logits, cache)."""

    def serve_step(params, cache, tokens):
        return zoo.decode_step(params, cfg, cache, tokens)

    return serve_step


def _eff_pure_dp(cfg, mesh, batch: int) -> bool:
    """pure_dp only pays off when the batch covers every chip."""
    return cfg.pure_dp and batch % mesh.devices.size == 0


def jit_train_step(cfg: ModelConfig, mesh, opt_cfg=None):
    """jit with explicit in/out shardings for the production mesh."""
    opt_cfg = opt_cfg or optim.AdamWConfig()
    install_act_rules(mesh, pure_dp=cfg.pure_dp)
    pspecs = zoo.param_specs(cfg)
    scalar = NamedSharding(mesh, P())
    step = make_train_step(cfg, opt_cfg)
    mode0 = "replicate" if cfg.pure_dp else "train"
    p_sh0 = shd.param_shardings(pspecs, mesh, mode0)
    o_sh0 = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        optim.init_specs(shd.param_specs_tree(pspecs, mesh, mode0), P()),
        is_leaf=lambda x: isinstance(x, P))

    def jit_for(batch_tree):
        B = jax.tree.leaves(batch_tree)[0].shape[0]
        eff = _eff_pure_dp(cfg, mesh, B)
        install_act_rules(mesh, pure_dp=eff)
        mode = "replicate" if eff else "train"
        p_sh = shd.param_shardings(pspecs, mesh, mode)
        o_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            optim.init_specs(shd.param_specs_tree(pspecs, mesh, mode), P()),
            is_leaf=lambda x: isinstance(x, P))
        b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.batch_specs(batch_tree, mesh, pure_dp=eff),
                            is_leaf=lambda x: isinstance(x, P))
        metrics_sh = {"loss": scalar, "lr": scalar, "grad_norm": scalar}
        # MoE routing telemetry: scalars + the [E] load vector, replicated
        metrics_sh.update({key: scalar for key in zoo.metric_zeros(cfg)})
        return jax.jit(step,
                       in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, metrics_sh),
                       donate_argnums=(0, 1))

    return jit_for, p_sh0, o_sh0


def jit_prefill_step(cfg: ModelConfig, mesh):
    """Inference prefill: (params, batch) → (logits, cache)."""
    install_act_rules(mesh, pure_dp=False)
    pspecs = zoo.param_specs(cfg)
    p_sh = shd.param_shardings(pspecs, mesh, "train")

    def step(params, batch):
        return zoo.prefill_step(params, cfg, batch)

    def jit_for(batch_tree):
        B = jax.tree.leaves(batch_tree)[0].shape[0]
        eff = _eff_pure_dp(cfg, mesh, B)
        install_act_rules(mesh, pure_dp=eff)
        mode = "replicate" if eff else "train"
        nonlocal p_sh
        p_sh = shd.param_shardings(pspecs, mesh, mode)
        b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.batch_specs(batch_tree, mesh, pure_dp=eff),
                            is_leaf=lambda x: isinstance(x, P))
        cache_shape = jax.eval_shape(step, pspecs, batch_tree)[1]
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.cache_specs(cache_shape, mesh, pure_dp=eff),
                            is_leaf=lambda x: isinstance(x, P))
        first = jax.tree.leaves(batch_tree)[0]
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        logit_spec = shd.spec_if_divisible(
            (first.shape[0], cfg.vocab), mesh, [dp, "model"])
        return jax.jit(step, in_shardings=(p_sh, b_sh),
                       out_shardings=(NamedSharding(mesh, logit_spec), c_sh))

    return jit_for, p_sh


def jit_serve_step(cfg: ModelConfig, mesh):
    install_act_rules(mesh, pure_dp=False)
    pspecs = zoo.param_specs(cfg)
    n_total = zoo.count_params(pspecs)
    step = make_serve_step(cfg)
    p_sh = shd.param_shardings(
        pspecs, mesh,
        "infer" if shd.infer_mode_fits(n_total, mesh) else "train")

    def jit_for(cache_tree, token_tree):
        B = token_tree.shape[0]
        eff = _eff_pure_dp(cfg, mesh, B)
        install_act_rules(mesh, pure_dp=eff)
        if eff:
            mode = "replicate"
        else:
            mode = "infer" if shd.infer_mode_fits(n_total, mesh) else "train"
        nonlocal p_sh
        p_sh = shd.param_shardings(pspecs, mesh, mode)
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.cache_specs(cache_tree, mesh, pure_dp=eff),
                            is_leaf=lambda x: isinstance(x, P))
        t_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.batch_specs(token_tree, mesh, pure_dp=eff),
                            is_leaf=lambda x: isinstance(x, P))
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        if eff:
            dp = dp + ("model",)
        logit_spec = shd.spec_if_divisible(
            (token_tree.shape[0], cfg.vocab), mesh,
            [dp, None if eff else "model"])
        out_sh = (NamedSharding(mesh, logit_spec), c_sh)
        return jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                       out_shardings=out_sh, donate_argnums=(1,))

    return jit_for, p_sh
