"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's 512 placeholder
devices to be configured first.

TPU v5e constants used by the roofline (benchmarks/roofline.py):
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over the real local device(s) for integration tests."""
    return jax.make_mesh(shape, axes)


def make_source_mesh(n_hosts: int | None = None):
    """1-D mesh whose single ``sources`` axis carries the serving
    runtime's source lanes (``repro.kernels.mesh`` /
    ``repro.serve.mesh``). Defaults to every local device — under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` that is the
    N simulated hosts the multihost bench and CI job use."""
    n = n_hosts or len(jax.devices())
    return jax.make_mesh((n,), ("sources",))


def enter_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    jax >= 0.6 exposes ``jax.set_mesh``; on the 0.4.x line the ``Mesh``
    object itself is the context manager with the same effect.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def data_axes(mesh) -> tuple:
    """The combined batch-sharding axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_chips(mesh) -> int:
    return mesh.devices.size
