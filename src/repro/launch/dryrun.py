import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 TPU v5e pods.
``.lower().compile()`` must succeed for every applicable cell;
``memory_analysis()`` proves per-chip fit; ``cost_analysis()`` +
collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only] [--out report.json]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro import optim  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.models import model_zoo as zoo  # noqa: E402

from . import sharding as shd  # noqa: E402
from . import steps  # noqa: E402
from .mesh import enter_mesh, make_production_mesh  # noqa: E402

_LINE_RE = re.compile(
    r"=\s+(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def parse_collectives(hlo_text: str) -> dict:
    """Sum collective *output* bytes per device, by op kind, from the
    optimized (post-SPMD) HLO. Result-type shapes (tuple or single) are
    the per-participant output buffers."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        size = 0
        for dt, dims in _SHAPE_RE.findall(m.group("rtype")):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            size += n * _DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0.0) + size
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": out, "counts": counts,
            "total_bytes": sum(out.values())}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               remat: str | None = None):
    """Lower + compile one cell; return the report dict."""
    cfg = configs.get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    steps.install_act_rules(mesh)
    t0 = time.time()
    with enter_mesh(mesh):
        ins = zoo.input_specs(cfg, shape)
        if shape.kind == "train":
            jit_for, p_sh, o_sh = steps.jit_train_step(cfg, mesh)
            batch = ins["batch"]
            pspecs = zoo.param_specs(cfg)
            ospecs = jax.eval_shape(optim.init, pspecs)
            jitted = jit_for(batch)
            lowered = jitted.lower(pspecs, ospecs, batch)
        elif shape.kind == "prefill":
            jit_for, p_sh = steps.jit_prefill_step(cfg, mesh)
            batch = ins["batch"]
            pspecs = zoo.param_specs(cfg)
            jitted = jit_for(batch)
            lowered = jitted.lower(pspecs, batch)
        else:
            jit_for, p_sh = steps.jit_serve_step(cfg, mesh)
            pspecs = zoo.param_specs(cfg)
            jitted = jit_for(ins["cache"], ins["tokens"])
            lowered = jitted.lower(pspecs, ins["cache"], ins["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    n_dev = mesh.devices.size

    def _get(obj, attr):
        try:
            return float(getattr(obj, attr))
        except Exception:
            return None

    mem_report = {}
    if mem is not None:
        for a in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_report[a] = _get(mem, a)

    flops = None
    bytes_accessed = None
    if cost:
        c = cost if isinstance(cost, dict) else cost[0]
        flops = c.get("flops")
        bytes_accessed = c.get("bytes accessed")

    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_report,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collectives": coll,
        "params_total": zoo.count_params(zoo.param_specs(cfg)),
        "params_active": zoo.active_params(
            cfg, zoo.count_params(zoo.param_specs(cfg))),
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a, s, applicable in configs.cells():
            if applicable:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    reports = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
            try:
                r = lower_cell(arch, shape, multi_pod=mp, remat=args.remat)
                mem_gb = (r["memory"].get("temp_size_in_bytes") or 0) / 2**30
                print(f"[OK]   {tag}: compile={r['compile_s']}s "
                      f"temp/dev={mem_gb:.2f}GiB "
                      f"flops/dev={r['flops_per_device'] and r['flops_per_device']:.3g} "
                      f"coll={r['collectives']['total_bytes']/2**20:.1f}MiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                r = {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if mp else "16x16",
                     "ok": False, "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}",
                      flush=True)
            reports.append(r)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1, default=str)
        print(f"wrote {args.out}")
    n_ok = sum(1 for r in reports if r.get("ok"))
    print(f"{n_ok}/{len(reports)} cells OK")
    return 0 if n_ok == len(reports) else 1


if __name__ == "__main__":
    raise SystemExit(main())
