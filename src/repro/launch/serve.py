"""Serving driver: prefill + decode with the CG request router.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
      --requests 64 --decode-steps 8 [--replicas 4] [--hetero]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model_zoo as zoo
from repro.serve import CGRequestRouter, ServingEngine

from . import steps
from .mesh import enter_mesh, make_smoke_mesh


def build_replica(cfg, params, decode_steps: int, slow: float = 0.0,
                  max_batch: int = 8, decode=None):
    """A replica fn: batch of token prompts → generated ids.

    Batches are padded to ``max_batch`` so the decode step keeps one
    fixed compiled shape (continuous-batching style). All replicas share
    one jitted ``decode`` (pass it in) — they serve the same model."""
    if decode is None:
        decode = jax.jit(lambda p, c, t: zoo.decode_step(p, cfg, c, t))

    def run(payloads):
        B = len(payloads)
        prompts = np.zeros((max_batch, 1), np.int32)
        prompts[:B] = np.asarray(payloads, np.int32).reshape(B, 1)
        cache = zoo.init_cache(cfg, max_batch, 64)
        tok = jnp.asarray(prompts[:, :1])
        out = []
        for _ in range(decode_steps):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok))
        if slow:
            time.sleep(slow)                                # heterogeneity
        return np.concatenate(out, axis=1)[:B]

    return run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=configs.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--hetero", action="store_true",
                    help="make one replica 5x slower (Fig 15 setup)")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    mesh = make_smoke_mesh()
    steps.install_act_rules(mesh)
    mesh_ctx = enter_mesh(mesh)
    mesh_ctx.__enter__()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))

    shared_decode = jax.jit(lambda p, c, t: zoo.decode_step(p, cfg, c, t))
    fns = []
    for r in range(args.replicas):
        slow = 0.05 if (args.hetero and r == 0) else 0.0
        fns.append(build_replica(cfg, params, args.decode_steps, slow,
                                 decode=shared_decode))
    engine = ServingEngine(fns, CGRequestRouter(args.replicas))

    rng = np.random.default_rng(0)
    zipf_keys = rng.zipf(1.3, size=args.requests) % 1000    # skewed sessions
    prompts = rng.integers(0, cfg.vocab, size=(args.requests, 1))
    t0 = time.time()
    engine.submit_batch(zipf_keys.astype(np.int32), list(prompts))
    served = 0
    while served < args.requests:
        served += engine.step()
    dt = time.time() - t0
    lat = np.asarray(engine.latencies)
    print(f"served {served} requests in {dt:.2f}s "
          f"({served/dt:.1f} req/s); latency mean {lat.mean()*1e3:.1f}ms "
          f"p99 {np.percentile(lat, 99)*1e3:.1f}ms; "
          f"router moves {engine.router.moves}; "
          f"per-replica served {[r.served for r in engine.replicas]}")


if __name__ == "__main__":
    main()
