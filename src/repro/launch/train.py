"""End-to-end training driver.

Wires together: CG-sharded data pipeline → jit'd train step (FSDP×TP
mesh) → AdamW → async checkpointing → straggler delegation → elastic
failure response. On this CPU container it runs the reduced (smoke)
configs end-to-end; on a fleet the same driver runs the full configs
(the dry-run proves those compile and fit).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 20 --batch 8 --seq 128 [--smoke] [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.checkpoint import checkpointer as ckpt
from repro.data import PipelineConfig, ShardedTokenPipeline
from repro.models import model_zoo as zoo
from repro.runtime import DelegationBalancer, FTConfig, FaultTolerantRunner

from . import steps
from .mesh import enter_mesh, make_smoke_mesh


def train(arch: str, n_steps: int = 20, batch: int = 8, seq: int = 128,
          smoke: bool = True, ckpt_dir: str = "/tmp/repro_ckpt",
          resume: bool = False, ckpt_every: int = 10,
          n_hosts: int = 4, lr: float = 3e-4, log_every: int = 1,
          fail_host_at: int | None = None):
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    mesh = make_smoke_mesh()
    steps.install_act_rules(mesh)
    mesh_ctx = enter_mesh(mesh)
    mesh_ctx.__enter__()
    opt_cfg = optim.AdamWConfig(lr_peak=lr, warmup_steps=max(2, n_steps // 10),
                                total_steps=n_steps)

    pipe = ShardedTokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, n_hosts=n_hosts))
    runner = FaultTolerantRunner(
        FTConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every),
        n_hosts=n_hosts, pipeline=pipe)
    balancer = DelegationBalancer(n_hosts)

    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    opt_state = optim.init(params)
    start_step = 0
    if resume:
        start_step, restored = runner.restore_latest(
            {"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            print(f"resumed from step {start_step}")

    # no donation here: freshly-initialized zero leaves can share a
    # deduped constant buffer, and donating it twice is an XLA error.
    train_step = jax.jit(steps.make_train_step(cfg, opt_cfg))

    def make_batch(step):
        tokens = pipe.global_batch(step)[:batch]
        b = {"tokens": tokens}
        if cfg.family == "audio":
            fkey = jax.random.fold_in(key, step)
            b["frames"] = jax.random.normal(
                fkey, (batch, seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            fkey = jax.random.fold_in(key, step)
            b["patches"] = jax.random.normal(
                fkey, (batch, cfg.n_patches, cfg.vision_dim), jnp.bfloat16)
        return b

    losses = []
    for step in range(start_step, n_steps):
        if fail_host_at is not None and step == fail_host_at:
            moved = runner.on_failure(n_hosts - 1)     # simulate a loss
            print(f"[ft] host {n_hosts-1} failed; re-paired shards: {moved}")
        t0 = time.time()
        params, opt_state, metrics = train_step(params, opt_state,
                                                make_batch(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        # worker delegation: hosts report step time; balancer re-pairs
        for h in range(n_hosts):
            if runner.hosts[h].alive:
                balancer.observe(h, dt * (1.0 + 0.05 * h))
                runner.heartbeat(h)
        balancer.rebalance(pipe)
        runner.maybe_save(step, {"params": params, "opt": opt_state})
        if step % log_every == 0:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
    runner.saver.wait()
    mesh_ctx.__exit__(None, None, None)
    return np.asarray(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (fleet scale) instead of smoke")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-host-at", type=int, default=None)
    args = ap.parse_args()
    losses = train(args.arch, n_steps=args.steps, batch=args.batch,
                   seq=args.seq, smoke=not args.full, resume=args.resume,
                   ckpt_dir=args.ckpt_dir, fail_host_at=args.fail_host_at)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
