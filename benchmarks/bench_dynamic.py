"""Paper Fig. 13 — resources change over time; CG re-adapts.

(y,z) schedule: (3,5) → (5,4) at ⅓ of the stream → (2,10) at ⅔.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cg, partitioners as P, simulation, streams

from .common import fmt, table, wp_keys

SLOT = 5_000


def run(m: int = 300_000, quick: bool = False):
    if quick:
        m = 150_000
    n = 10
    keys = wp_keys(m)
    slots = m // SLOT
    caps = np.zeros((slots, n))
    for start, c in streams.dynamic_capacity_schedule(n, m):
        caps[start // SLOT:] = c / 0.8
    capsj = jnp.asarray(caps, jnp.float32)

    kg = simulation.simulate_queues(P.key_grouping(keys, n), capsj, n, SLOT)
    sg = simulation.simulate_queues(P.shuffle_grouping(keys, n), capsj, n, SLOT)
    # runtime block path (block_size=128): dynamics figures are
    # robust to block staleness; precision figures pin block_size=0
    res = cg.run(cg.CGConfig(n_workers=n, alpha=20, eps=0.01, slot_len=SLOT,
                             max_moves_per_slot=16), keys, capsj)

    third = slots // 3
    marks = [1, third - 1, third + 1, 2 * third - 1, 2 * third + 1, slots - 1]
    rows = []
    for name, s in [("KG", kg.imbalance), ("SG", sg.imbalance),
                    ("CG", res.imbalance)]:
        rows.append([name, *(fmt(float(np.asarray(s)[i]), 2) for i in marks)])
    print(table("Fig 13 — imbalance around capacity changes "
                "(cols: start, pre/post change-1, pre/post change-2, end)",
                ["algo", *(f"t{i}" for i in marks)], rows))
    rows = []
    for name, s in [("KG", kg.queue_spread), ("SG", sg.queue_spread),
                    ("CG", res.queue_spread)]:
        rows.append([name, *(fmt(float(np.asarray(s)[i]), 0) for i in marks)])
    print(table("Fig 13 — queue spread around capacity changes",
                ["algo", *(f"t{i}" for i in marks)], rows))
    print(f"paper-claim check: CG imbalance spikes at each change then "
          f"re-converges (moves={int(res.moves)}); KG/SG keep diverging")


if __name__ == "__main__":
    run()
