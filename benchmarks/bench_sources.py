"""Paper Fig. 11 — CG stability across 1/10/50/100 sources.

Sources partition the stream round-robin (the paper assigns messages to
sources by SG); each source routes against its local load view — a
shared merged base plus its own unpublished delta — synchronized by
delta-merge every ``sync_every`` routing steps (§V-C piggybacking).
The whole figure is one ``ref_porc_multisource`` call per point
(vmapped across sources), so it also reports throughput; the old
implementation looped a slow strict-cap engine over every source in
Python and made this the slowest figure in the suite, which is why the
100-source point used to be quarantined from quick mode.

The gate section reproduces that legacy per-source loop at the gate
point only and asserts the engine beats it ≥5× at S=50 with normalized
imbalance within 2× — plus S=1 bit-exactness against the single-source
block path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.kernels.ref import ref_porc_multisource, ref_porc_route

from .common import fmt, record, table, time_median, wp_keys

# Per-source routing granularity for this figure: block=1 is the
# paper's one-message-per-unit-time semantics per source (zero in-block
# staleness; the vmap over sources is what makes it fast). The sync
# period in messages is then S·sync_every.
BLOCK = 1


def _strict_loop(keys_np: np.ndarray, s: int, vws: int, eps: float):
    """The legacy Fig-11 path: one strict-cap engine call per source,
    fully independent load views, Python loop."""
    m = len(keys_np)
    assign_vw = np.empty(m, np.int32)
    for i in range(s):
        a, _ = ref_porc_route(jnp.asarray(keys_np[i::s]), vws, eps=eps,
                              engine="strict")
        assign_vw[i::s] = np.asarray(a)
    return jnp.asarray(assign_vw)


def _gate(keys, m: int, eps: float, quick: bool):
    """Exactness + speed/imbalance gate vs the legacy per-source loop."""
    # (a) S=1, sync_every=1 must be bit-identical to the single-source
    # block path (any block size; use the runtime default 128)
    short = keys[:8192]
    a_ref, _ = ref_porc_route(short, 100, block=128, eps=eps)
    a_ms, _ = ref_porc_multisource(short, 100, 1, sync_every=1, block=128,
                                   eps=eps)
    ms1_exact = bool((np.asarray(a_ref) == np.asarray(a_ms)).all())
    assert ms1_exact, "multisource S=1 diverged from ref_porc_route"

    # (b) ≥5x over the looped strict path at S=50, imbalance within 2x
    n, vws = 10, 100
    caps = jnp.ones(n) / n
    keys_np = np.asarray(keys)
    rows = []
    min_speedup = None
    for s in (50,) if quick else (50, 100):
        t_loop, a_loop = time_median(
            lambda: _strict_loop(keys_np, s, vws, eps), reps=1)
        imb_loop = float(metrics.normalized_imbalance(
            jnp.asarray(np.asarray(a_loop) % n, jnp.int32), caps))
        t_ms, (a_ms, _) = time_median(
            lambda: ref_porc_multisource(keys, vws, s, sync_every=1,
                                         block=BLOCK, eps=eps), reps=3)
        imb_ms = float(metrics.normalized_imbalance(
            jnp.asarray(np.asarray(a_ms) % n, jnp.int32), caps))
        speedup = t_loop / t_ms
        ratio = imb_ms / max(imb_loop, 1e-9)
        record("sources", section="gate", sources=s, n_workers=n, m=m,
               loop_s=t_loop, engine_s=t_ms, speedup=speedup,
               imbalance_loop=imb_loop, imbalance_engine=imb_ms,
               imbalance_ratio=ratio, ms1_exact=ms1_exact)
        rows.append([s, fmt(t_loop * 1e3, 1), fmt(t_ms * 1e3, 1),
                     fmt(speedup, 1), fmt(imb_loop, 4), fmt(imb_ms, 4),
                     fmt(ratio, 2)])
        assert speedup >= 5.0, \
            f"multisource engine too slow at S={s}: {speedup:.1f}x < 5x"
        assert ratio <= 2.0, \
            f"multisource imbalance off envelope at S={s}: {ratio:.2f}x > 2x"
        if min_speedup is None or speedup < min_speedup:
            min_speedup = speedup
    print(table(f"Gate — multisource engine vs legacy per-source loop "
                f"(m={m}, {vws} VWs, eps={eps})",
                ["sources", "loop ms", "engine ms", "speedup",
                 "imb loop", "imb engine", "ratio"], rows))
    record("sources", section="gate_summary", ms1_exact=ms1_exact,
           min_speedup=min_speedup)


def run(m: int = 128_000, quick: bool = False):
    # all source counts run in both modes — the engine un-quarantines
    # the 100-source point that the per-source loop made too slow
    srcs = (1, 10, 50, 100)
    ns = (10, 50) if quick else (5, 10, 50, 100)
    if quick:
        m = 64_000
    eps = 0.01
    keys = jnp.asarray(wp_keys(m))
    n_keys = 130_000
    rows = []
    for n in ns:
        vws = n * 10
        caps = jnp.ones(n) / n
        for s in srcs:
            t_ms, (a_vw, _) = time_median(
                lambda: ref_porc_multisource(keys, vws, s, sync_every=1,
                                             block=BLOCK, eps=eps))
            a_w = jnp.asarray(np.asarray(a_vw) % n, jnp.int32)
            imb = float(metrics.normalized_imbalance(a_w, caps))
            mem = int(metrics.memory_footprint(a_w, keys, n, n_keys))
            rate = m / t_ms
            record("sources", n_workers=n, sources=s, imbalance=imb,
                   memory=mem, msgs_per_sec=rate, wall_s=t_ms)
            rows.append([n, s, fmt(imb, 4), mem, fmt(rate / 1e6, 2)])
    print(table("Fig 11 — CG/PoRC imbalance, memory & throughput vs "
                "#sources (WP)",
                ["workers", "sources", "imbalance", "memory", "M msg/s"],
                rows))

    # sync-period knob: staleness window = S·sync_every messages
    rows = []
    n, vws = 10, 100
    caps = jnp.ones(n) / n
    for s in (10, 100):
        for sync_every in (1, 8, 64):
            t_ms, (a_vw, _) = time_median(
                lambda: ref_porc_multisource(keys, vws, s,
                                             sync_every=sync_every,
                                             block=BLOCK, eps=eps))
            imb = float(metrics.normalized_imbalance(
                jnp.asarray(np.asarray(a_vw) % n, jnp.int32), caps))
            record("sources", section="sync_sweep", sources=s,
                   sync_every=sync_every, imbalance=imb,
                   msgs_per_sec=m / t_ms)
            rows.append([s, sync_every, s * sync_every, fmt(imb, 4),
                         fmt(m / t_ms / 1e6, 2)])
    print(table(f"Sync-period tradeoff ({vws} VWs, block={BLOCK})",
                ["sources", "sync_every", "window msgs", "imbalance",
                 "M msg/s"], rows))

    _gate(keys, m, eps=0.01, quick=quick)
    print("paper-claim check: imbalance and memory stay flat (log scale) "
          "as sources grow 1→100 — local load views suffice")


if __name__ == "__main__":
    run()
