"""Paper Fig. 11 — CG stability across 1/10/50/100 sources.

Sources partition the stream round-robin (the paper assigns messages to
sources by SG); each source routes its substream with its own local
load view (the paper's eventual consistency) using the batched PoRC
kernel, then assignments merge.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.kernels.ref import ref_porc_route

from .common import fmt, record, table, wp_keys


def run(m: int = 131_072, quick: bool = False):
    srcs = (1, 10, 50) if quick else (1, 10, 50, 100)
    ns = (10, 50) if quick else (5, 10, 50, 100)
    if quick:
        m = 65_536     # the strict-cap engine is the slow (exact) path
    keys = np.asarray(wp_keys(m))
    n_keys = 130_000
    rows = []
    for n in ns:
        vws = n * 10
        caps = jnp.ones(n) / n
        for s in srcs:
            # round-robin split across sources; each source routes with
            # an independent (local) load estimate
            assign_vw = np.empty(m, np.int32)
            for i in range(s):
                # strict-cap engine: at 100 sources a substream's mean
                # per-VW load is ~1-5 messages, so snapshot staleness
                # would dominate the eps mechanism this figure measures
                a, _ = ref_porc_route(jnp.asarray(keys[i::s]), vws,
                                      eps=0.01, engine="strict")
                assign_vw[i::s] = np.asarray(a)
            a_w = jnp.asarray(assign_vw % n, jnp.int32)
            imb = float(metrics.normalized_imbalance(a_w, caps))
            mem = int(metrics.memory_footprint(a_w, jnp.asarray(keys),
                                               n, n_keys))
            record("sources", n_workers=n, sources=s, imbalance=imb,
                   memory=mem)
            rows.append([n, s, fmt(imb, 4), mem])
    print(table("Fig 11 — CG/PoRC imbalance & memory vs #sources (WP)",
                ["workers", "sources", "imbalance", "memory"], rows))
    print("paper-claim check: imbalance and memory stay flat (log scale) "
          "as sources grow 1→100 — local load views suffice")


if __name__ == "__main__":
    run()
