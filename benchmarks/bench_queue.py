"""Paper Figs. 9/10 — queue length, latency, utilization over time.

Fig 9: homogeneous cluster (10 workers @ 80%): KG diverges, CG flat.
Fig 10: heterogeneous (y=3 workers z=5× faster): KG & SG diverge, CG ≈ 0.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cg, partitioners as P, simulation, streams

from .common import fmt, table, wp_keys

SLOT = 10_000


def _report(tag, series, slots_to_show=5):
    idx = np.linspace(0, len(series[0][1]) - 1, slots_to_show).astype(int)
    rows = []
    for name, s in series:
        rows.append([name, *(fmt(float(np.asarray(s)[i]), 1) for i in idx)])
    print(table(tag, ["algo", *(f"t{i}" for i in idx)], rows))


def run(m: int = 300_000, quick: bool = False):
    if quick:
        m = 150_000
    keys = wp_keys(m)
    n = 10

    # CG runs on the runtime block path (CGConfig.block_size=128):
    # this figure measures queue/latency *dynamics*, which hold within
    # block staleness (verified vs the exact oracle); imbalance-precision
    # figures (epsilon, schemes_workers) pin block_size=0 instead.
    # ---- Fig 9: homogeneous ----
    caps = jnp.full((n,), 1.25 / n)
    kg = simulation.simulate_queues(P.key_grouping(keys, n), caps, n, SLOT)
    res = cg.run(cg.CGConfig(n_workers=n, alpha=10, eps=0.01,
                             slot_len=SLOT), keys, caps)
    _report("Fig 9 — max-min queue length over time (homogeneous)",
            [("KG", kg.queue_spread), ("CG", res.queue_spread)])
    _report("Fig 9 — max-min latency over time (homogeneous)",
            [("KG", kg.latency_spread), ("CG", res.latency_spread)])

    # ---- Fig 10: heterogeneous y=3, z=5 ----
    capsh = jnp.asarray(streams.heterogeneous_capacities(n, 3, 5.0) / 0.8,
                        jnp.float32)
    kg = simulation.simulate_queues(P.key_grouping(keys, n), capsh, n, SLOT)
    sg = simulation.simulate_queues(P.shuffle_grouping(keys, n), capsh, n, SLOT)
    res = cg.run(cg.CGConfig(n_workers=n, alpha=10, eps=0.01,
                             slot_len=SLOT), keys, capsh)
    _report("Fig 10 — max-min queue length (heterogeneous y=3 z=5)",
            [("KG", kg.queue_spread), ("SG", sg.queue_spread),
             ("CG", res.queue_spread)])
    _report("Fig 10 — imbalance (heterogeneous)",
            [("KG", kg.imbalance), ("SG", sg.imbalance),
             ("CG", res.imbalance)])
    print("paper-claim check: KG and SG queue spread grow with time under "
          "heterogeneity; CG stays near zero after convergence "
          f"(CG moves={int(res.moves)})")


if __name__ == "__main__":
    run()
