"""Heavy-hitter-aware probing (D/W-Choices, arXiv:1510.05714) — the
skew × scale sweep gating replication AND imbalance at once.

Three asserts ride this bench in CI:

* **parity** — the neutral policy (threshold off, plain-chain budgets,
  argmin fallback) routes bit-identically to policy-free PoRC, single-
  and multi-source: the defaults-off = today's-PoRC guarantee.
* **replication** — W-Choices at 1000 workers stays ≤ 2× unique keys at
  every skew where the Eq.-2 lower bound admits it, and within 1.5× of
  that bound where the bound itself exceeds 2 (extreme skew leaves a
  few hundred unique keys, so the hottest key's balanced spread
  ceil(p₁·n/(1+eps)) dominates the factor — no scheme can do better).
* **imbalance** — W-Choices imbalance stays within the PoRC envelope
  (PoRC + 0.05) across the whole grid: the extra probe depth for heavy
  keys must not cost balance.

D-Choices is recorded for the playbook numbers (its replication is the
lowest of all, but imbalance explodes once ceil(p₁·n/(1+eps)) exceeds
d_heavy — see docs/partitioners.md for when to pick which).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, streams
from repro.kernels.ref import (HHPolicy, neutral_hh_policy,
                               ref_porc_multisource, ref_porc_route)

from .common import fmt, record, table, time_median

EPS = 0.05
N_KEYS = 65_536


def _route(keys, n, policy):
    a, _ = ref_porc_route(keys, n, block=128, eps=EPS, policy=policy)
    return a


def _parity_gate():
    """Neutral policy ≡ plain PoRC, bit for bit (the CI parity gate)."""
    n = 100
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), 20_000,
                                      N_KEYS, 1.6)
    plain = np.asarray(_route(keys, n, None))
    neut = np.asarray(_route(keys, n, neutral_hh_policy(n)))
    single = bool((plain == neut).all())

    ms_keys = keys[:19_968]
    pl, _ = ref_porc_multisource(ms_keys, n, 4, sync_every=2, block=64,
                                 eps=EPS)
    ne, _ = ref_porc_multisource(ms_keys, n, 4, sync_every=2, block=64,
                                 eps=EPS, policy=neutral_hh_policy(n))
    multi = bool((np.asarray(pl) == np.asarray(ne)).all())

    assert single, "neutral policy diverged from plain PoRC (single-source)"
    assert multi, "neutral policy diverged from plain PoRC (multi-source)"
    record("hh_probing", section="parity", parity=single, ms_parity=multi)
    print(f"parity gate: neutral policy bit-identical to PoRC "
          f"(single={single}, S=4 multisource={multi})")


def _sweep(m: int, quick: bool):
    zs = (0.8, 1.4, 2.0) if quick else (0.8, 1.1, 1.4, 1.7, 2.0)
    ns = (100, 1000)
    schemes = [("PORC", None),
               ("DCHOICES", HHPolicy(scheme="d")),
               ("WCHOICES", HHPolicy(scheme="w"))]
    rows, gate_fail = [], []
    for z in zs:
        keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), m,
                                          N_KEYS, z)
        uniq, cnt = np.unique(np.asarray(keys), return_counts=True)
        lb = float(metrics.replication_lower_bound(
            jnp.asarray(cnt / m), 1000, EPS)) / len(uniq)
        for n in ns:
            caps = jnp.ones(n) / n
            stats = {}
            for name, pol in schemes:
                a = _route(keys, n, pol)
                imb = float(metrics.normalized_imbalance(a, caps))
                repl = float(metrics.memory_footprint(
                    a, keys, n, N_KEYS)) / len(uniq)
                stats[name] = (imb, repl)
                extra = {"repl_bound": lb} if n == 1000 else {}
                record("hh_probing", section="sweep", z=z, n_bins=n,
                       scheme=name, imbalance=imb, replication=repl,
                       **extra)
            rows.append([z, n,
                         *(fmt(stats[s][0], 3) for s, _ in schemes),
                         *(fmt(stats[s][1], 2) for s, _ in schemes),
                         fmt(lb, 2) if n == 1000 else "-"])
            if n == 1000:
                imb_p, _ = stats["PORC"]
                imb_w, repl_w = stats["WCHOICES"]
                # replication: ≤ 2× where Eq. 2 admits it, else within
                # 1.5× of the bound (see module docstring)
                if repl_w > max(2.0, 1.5 * lb):
                    gate_fail.append(
                        f"z={z}: W replication {repl_w:.2f} > "
                        f"max(2, 1.5*{lb:.2f})")
                if imb_w > imb_p + 0.05:
                    gate_fail.append(
                        f"z={z}: W imbalance {imb_w:.3f} > "
                        f"PoRC {imb_p:.3f} + 0.05")
    print(table(
        f"D/W-Choices vs PoRC — skew × workers (m={m}, eps={EPS})",
        ["z", "workers", "imb PoRC", "imb D", "imb W",
         "repl PoRC", "repl D", "repl W", "Eq2 lb@1000"], rows))
    assert not gate_fail, "; ".join(gate_fail)
    record("hh_probing", section="gate_summary", gate="pass",
           m=m, n_gate=1000)
    print("gate: W-Choices @1000 workers — replication ≤ max(2, 1.5×Eq2) "
          "and imbalance ≤ PoRC+0.05 at every z: pass")


def _throughput(quick: bool):
    """Informational: what the sketch + deep chains cost on this host."""
    n, m = 100, 65_536 if quick else 262_144
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(2), m, N_KEYS, 1.4)
    rows = []
    for name, pol in [("PORC", None), ("DCHOICES", HHPolicy(scheme="d")),
                      ("WCHOICES", HHPolicy(scheme="w"))]:
        t, _ = time_median(lambda: _route(keys, n, pol), reps=3)
        rate = m / t
        record("hh_probing", section="throughput", scheme=name, m=m,
               n_bins=n, msgs_per_sec=rate)
        rows.append([name, fmt(t * 1e3, 1), fmt(rate / 1e6, 2)])
    print(table(f"policy-path throughput (m={m}, {n} workers, block=128)",
                ["scheme", "ms", "M msg/s"], rows))
    print("note: D/W pay for the sketch and a d_heavy/n-deep candidate "
          "chain; the tradeoff they buy is the replication column above")


def run(m: int = 262_144, quick: bool = False):
    if quick:
        m = min(m, 131_072)
    _parity_gate()
    _sweep(m, quick)
    _throughput(quick)


if __name__ == "__main__":
    run(quick=True)
