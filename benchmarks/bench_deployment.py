"""Paper Figs. 14/15 — Storm-deployment analogue: throughput & latency.

Setup mirrors §VII-Q4: 8 sources / 24 workers, TW-like stream, fixed
per-message CPU cost (0.1–1 ms sweep), homogeneous vs heterogeneous
(two executors cpulimit'ed to 30%). The discrete-event queueing model
(core.simulation.simulate_deployment) supplies throughput and latency.

Headline paper numbers to reproduce under heterogeneity:
CG ≥ 2× KG throughput and ≈3.44× better latency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cg, partitioners as P, simulation, streams

from .common import fmt, record, table

WORKERS = 24


def _assignments(keys, caps):
    """CG routes against the scenario's capacities (it adapts); the
    static schemes are capacity-oblivious by definition."""
    out = {"KG": P.key_grouping(keys, WORKERS),
           "PKG": P.partial_key_grouping(keys, WORKERS),
           "SG": P.shuffle_grouping(keys, WORKERS)}
    # runtime block path (block_size=128): dynamics figures are
    # robust to block staleness; precision figures pin block_size=0
    res = cg.run(cg.CGConfig(n_workers=WORKERS, alpha=20, eps=0.01,
                             slot_len=5_000, max_moves_per_slot=16),
                 keys, caps)
    # steady-state CG routing = the last third of the stream
    m = keys.shape[0]
    out["CG"] = res.assignment[2 * m // 3:]
    return out


def run(m: int = 200_000, quick: bool = False):
    if quick:
        m = 100_000
    keys = streams.sample_trace(jax.random.PRNGKey(0), streams.TW_TRACE, m)
    service_sweep = (0.25, 0.5) if quick else (0.1, 0.25, 0.5, 1.0)

    for tag, frac in [("homogeneous (Fig 14)", np.ones(WORKERS)),
                      ("heterogeneous: 2 workers @30% (Fig 15)",
                       np.concatenate([[0.3, 0.3], np.ones(WORKERS - 2)]))]:
        fr = jnp.asarray(frac, jnp.float32)
        # CG sees service rates ∝ cpu fractions at ρ = 0.8
        caps = jnp.asarray(frac / frac.sum() / 0.8, jnp.float32)
        assigns = _assignments(keys, caps)
        rows = []
        for sms in service_sweep:
            # offer 75% of aggregate capacity: a balanced scheme is
            # stable, a skew-blind one saturates its worst worker
            offered = float(frac.sum()) / (sms * 1e-3) * 0.75
            res = {}
            for name, a in assigns.items():
                res[name] = simulation.simulate_deployment(
                    a, WORKERS, sms, fr, offered_rate_per_s=offered)
            row = [sms]
            for name in ("KG", "PKG", "SG", "CG"):
                r = res[name]
                record("deployment", scenario=tag, service_ms=sms,
                       scheme=name, msgs_per_sec=float(r.throughput),
                       mean_latency_ms=float(r.mean_latency_ms),
                       max_latency_ms=float(r.max_latency_ms))
                row.append(fmt(float(r.throughput) / 1000, 1))
                row.append(fmt(float(r.mean_latency_ms), 2))
            cgr, kgr = res["CG"], res["KG"]
            row.append(fmt(float(cgr.throughput / jnp.maximum(kgr.throughput,
                                                              1e-9)), 2))
            row.append(fmt(float(kgr.mean_latency_ms /
                                 jnp.maximum(cgr.mean_latency_ms, 1e-9)), 2))
            rows.append(row)
        print(table(
            f"Fig 14/15 — TW deployment, {tag}",
            ["svc_ms", "KG kq/s", "KG ms", "PKG kq/s", "PKG ms",
             "SG kq/s", "SG ms", "CG kq/s", "CG ms",
             "CG/KG thr", "KG/CG lat"], rows))
    print("paper-claim check: heterogeneous CG/KG throughput ≥ 2×, "
          "KG/CG latency ratio ≥ 3.4× at the saturation service costs")


if __name__ == "__main__":
    run()
