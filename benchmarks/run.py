"""Benchmark driver: one module per paper figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                          [--out BENCH_results.json]

Every run writes a single ``BENCH_results.json`` (per-figure wall time
plus the structured rows each module records — msgs/sec, imbalance,
memory) which CI uploads as an artifact; diffing those files across
commits is the benchmark regression signal.
"""
from __future__ import annotations

import argparse
import inspect
import platform
import time

import jax

from . import (bench_deployment, bench_dynamic, bench_epsilon,
               bench_failures, bench_heterogeneous, bench_hh_probing,
               bench_moe_router, bench_moe_train, bench_multihost,
               bench_porc_schemes, bench_queue, bench_schemes_workers,
               bench_sources, bench_virtual_workers, common, roofline)

ALL = [
    ("porc_schemes", bench_porc_schemes),      # Fig 4 + block-path gate
    ("epsilon", bench_epsilon),                # Fig 6
    ("schemes_workers", bench_schemes_workers),  # Fig 7/8
    ("queue", bench_queue),                    # Fig 9/10
    ("sources", bench_sources),                # Fig 11
    ("virtual_workers", bench_virtual_workers),  # Fig 12
    ("dynamic", bench_dynamic),                # Fig 13
    ("deployment", bench_deployment),          # Fig 14/15
    ("heterogeneous", bench_heterogeneous),    # Figs 9/10+12/13+15 via
                                               # the delegation runtime
    ("hh_probing", bench_hh_probing),          # D/W-Choices skew sweep
                                               # (arXiv:1510.05714)
    ("failures", bench_failures),              # kill-1-of-8 chaos +
                                               # migration-cost metering
    ("moe_router", bench_moe_router),          # beyond paper
    ("moe_train", bench_moe_train),            # end-to-end MoE training:
                                               # topk vs CG x uniform vs
                                               # skewed expert capacity
    ("multihost", bench_multihost),            # mesh-sharded serving
                                               # across simulated hosts
    ("roofline", roofline),                    # §Roofline
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="BENCH_results.json",
                    help="results JSON path ('' disables)")
    args = ap.parse_args()
    names = [n for n, _ in ALL]
    if args.only and args.only not in names:
        raise SystemExit(f"unknown --only {args.only!r}; "
                         f"choose from: {', '.join(names)}")
    common.start_run({
        "quick": args.quick,
        "only": args.only,
        "backend": jax.default_backend(),
        "device": jax.devices()[0].device_kind,
        "platform": platform.platform(),
        "started_unix": round(time.time(), 1),
    })
    t0 = time.time()
    failed = []
    for name, mod in ALL:
        if args.only and args.only != name:
            continue
        t = time.time()
        print(f"\n{'='*72}\n[{name}]")
        accepts_quick = "quick" in inspect.signature(mod.run).parameters
        err = None
        try:
            mod.run(quick=args.quick) if accepts_quick else mod.run()
        except Exception as e:  # noqa: BLE001 — keep the sweep going
            err = f"{type(e).__name__}: {e}"
            failed.append(name)
            common.record(name, error=err)
        common.note_timing(name, time.time() - t)
        status = "done in" if err is None else "FAILED after"
        print(f"[{name}] {status} {time.time()-t:.1f}s"
              + (f": {err}" if err else ""), flush=True)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")
    if args.out:
        path = common.write_results(args.out)
        print(f"wrote {path}")
    if failed:
        raise SystemExit(f"benchmarks failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
