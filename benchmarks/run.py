"""Benchmark driver: one module per paper figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import time

from . import (bench_deployment, bench_dynamic, bench_epsilon,
               bench_moe_router, bench_porc_schemes, bench_queue,
               bench_schemes_workers, bench_sources,
               bench_virtual_workers, roofline)

ALL = [
    ("porc_schemes", bench_porc_schemes),      # Fig 4
    ("epsilon", bench_epsilon),                # Fig 6
    ("schemes_workers", bench_schemes_workers),  # Fig 7/8
    ("queue", bench_queue),                    # Fig 9/10
    ("sources", bench_sources),                # Fig 11
    ("virtual_workers", bench_virtual_workers),  # Fig 12
    ("dynamic", bench_dynamic),                # Fig 13
    ("deployment", bench_deployment),          # Fig 14/15
    ("moe_router", bench_moe_router),          # beyond paper
    ("roofline", roofline),                    # §Roofline
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    t0 = time.time()
    for name, mod in ALL:
        if args.only and args.only != name:
            continue
        t = time.time()
        print(f"\n{'='*72}\n[{name}]")
        try:
            mod.run(quick=args.quick)
        except TypeError:
            mod.run()
        print(f"[{name}] done in {time.time()-t:.1f}s", flush=True)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
