"""§Roofline — three-term roofline per (arch × shape × mesh) cell.

Sources
-------
* ``results/dryrun/*.json`` — the compiled dry-run artifacts:
  memory_analysis (per-device bytes), cost_analysis flops (RAW —
  undercounts lax.scan bodies, see note), parsed per-device collective
  bytes from the post-SPMD HLO.
* Analytic FLOP/byte model below — exact matmul dims from the configs,
  with documented factors for backward (2×fwd), full-remat recompute
  (+1×fwd) and the baseline's causal-waste in chunked attention (it
  computes all chunk pairs). This is the scan-corrected compute/memory
  number; EXPERIMENTS.md §Dry-run records the raw cost_analysis values
  alongside.

Terms (TPU v5e):
  compute    = FLOPs / (chips · 197e12)
  memory     = HBM bytes / (chips · 819e9)
  collective = collective bytes per device / 50e9

MODEL_FLOPS (useful) = 6·N_active·tokens (train) or 2·N_active·tokens
(inference) + causality-honoring attention flops. The ratio
useful/computed exposes remat + causal waste.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from repro import configs
from repro.configs.base import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

from .common import fmt, record, table, time_median, wp_keys

# CPU-backend bf16→f32 legalization inflates temp memory vs a native-bf16
# TPU program; measured 2.1× on the layer microbenchmark (DESIGN.md §9 /
# EXPERIMENTS.md §Dry-run methodology).
CPU_BF16_TEMP_FACTOR = 2.1


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes
# ---------------------------------------------------------------------------

def _attn_flops(cfg, B, S, causal_honored: bool):
    """Attention matmul flops (qk + av), forward, whole model."""
    if cfg.family == "ssm":
        return 0.0
    H, Dh = cfg.n_heads, cfg.d_head
    if cfg.family == "hybrid":
        L_attn = cfg.n_layers // cfg.shared_attn_every
        f = 4.0 * B * S * S * H * Dh * L_attn
        return f / 2 if causal_honored else f
    if cfg.family == "audio":
        L = cfg.n_layers
        enc = 4.0 * B * S * S * H * Dh * cfg.n_enc_layers
        dec_self = 4.0 * B * S * S * H * Dh * L
        cross = 4.0 * B * S * S * H * Dh * L
        if causal_honored:
            dec_self /= 2
        return enc + dec_self + cross
    L = cfg.n_layers
    if cfg.sliding_window:
        ge = cfg.global_every
        n_glob = (L // ge) if ge else 0
        n_loc = L - n_glob
        W = min(cfg.sliding_window, S)
        f = 4.0 * B * H * Dh * (n_loc * S * W + n_glob * S * S)
    else:
        f = 4.0 * B * H * Dh * L * S * S
    return f / 2 if causal_honored else f


def _ssd_flops(cfg, B, S):
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    Q = min(s.chunk, S)
    # per chunk per head: CBᵀ (2Q²N) + WX (2Q²P) + state in/out (4QPN)
    per_head = 2.0 * Q * Q * (s.d_state + s.head_dim) \
        + 4.0 * Q * s.head_dim * s.d_state
    return B * (S / Q) * H * per_head * cfg.n_layers


def cell_flops(cfg, shape, n_active):
    """(useful, computed) model flops, whole step, all chips."""
    B, S = shape.global_batch, shape.seq_len
    # triangular attention schedule (§Perf H1): computed attention ≈
    # causal-honoring with one boundary chunk of slack per q chunk
    tri_slack = 1.0 + 1.0 / max(S // cfg.q_chunk, 1)
    if shape.kind == "train":
        tokens = B * S
        fwd_useful = 2.0 * n_active * tokens \
            + _attn_flops(cfg, B, S, True) + _ssd_flops(cfg, B, S)
        fwd_computed = 2.0 * n_active * tokens \
            + _attn_flops(cfg, B, S, True) * tri_slack + _ssd_flops(cfg, B, S)
        useful = 3.0 * fwd_useful            # fwd + bwd(2×)
        computed = 4.0 * fwd_computed        # + full-remat re-forward
        return useful, computed
    if shape.kind == "prefill":
        tokens = B * S
        useful = 2.0 * n_active * tokens \
            + _attn_flops(cfg, B, S, True) + _ssd_flops(cfg, B, S)
        computed = 2.0 * n_active * tokens \
            + _attn_flops(cfg, B, S, True) * tri_slack + _ssd_flops(cfg, B, S)
        return useful, computed
    # decode: one token against S of history
    tokens = B
    f = 2.0 * n_active * tokens
    if cfg.family in ("ssm",):
        attn = 0.0
    elif cfg.family == "hybrid":
        L_attn = cfg.n_layers // cfg.shared_attn_every
        attn = 4.0 * B * S * cfg.n_heads * cfg.d_head * L_attn
    elif cfg.family == "audio":
        attn = 4.0 * B * S * cfg.n_heads * cfg.d_head * cfg.n_layers * 2
    elif cfg.sliding_window and cfg.global_every:
        L = cfg.n_layers
        ng = L // cfg.global_every
        attn = 4.0 * B * cfg.n_heads * cfg.d_head * (
            (L - ng) * min(cfg.sliding_window, S) + ng * S)
    else:
        attn = 4.0 * B * S * cfg.n_heads * cfg.d_head * cfg.n_layers
    return f + attn, f + attn


def cell_bytes(cfg, shape, n_total, report):
    """Analytic HBM bytes per device per step (whole-step traffic)."""
    chips = report["n_devices"]
    B, S = shape.global_batch, shape.seq_len
    p_bf16 = 2.0 * n_total / chips
    if shape.kind == "train":
        # params: fwd read + remat re-read + bwd read + write (4×);
        # optimizer m/v/master read+write (2 × 12B/param)
        param_traffic = 4.0 * p_bf16 + 2.0 * 12.0 * n_total / chips
        # activations: saved residual stream write+read (seq-sharded)
        carry = 2.0 * cfg.n_layers * B * S * cfg.d_model * 2.0 / chips
        # transient traffic proxy: corrected temp touched ~2×
        temp = (report["memory"].get("temp_size_in_bytes") or 0)
        transient = 2.0 * temp / CPU_BF16_TEMP_FACTOR
        return param_traffic + carry + transient
    if shape.kind == "prefill":
        param_traffic = p_bf16
        temp = (report["memory"].get("temp_size_in_bytes") or 0)
        return param_traffic + 2.0 * temp / CPU_BF16_TEMP_FACTOR
    # decode: read all (active) params once + read/write KV cache slice
    act_bytes = 2.0 * report["params_active"] / chips
    cache_bytes = (report["memory"].get("argument_size_in_bytes") or 0) * 0.5
    return act_bytes + cache_bytes


# ---------------------------------------------------------------------------
# Table builder
# ---------------------------------------------------------------------------

def load_reports(results_dir="results/dryrun"):
    reps = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(f))[0]
        if r.get("ok"):
            reps.append(r)
    return reps


def analyze(report):
    cfg = configs.get_config(report["arch"])
    shape = SHAPES[report["shape"]]
    chips = report["n_devices"]
    useful, computed = cell_flops(cfg, shape, report["params_active"])
    t_comp = computed / (chips * PEAK_FLOPS)
    t_useful = useful / (chips * PEAK_FLOPS)
    hbm = cell_bytes(cfg, shape, report["params_total"], report)
    t_mem = hbm / HBM_BW
    t_coll = report["collectives"]["total_bytes"] / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    frac = t_useful / max(t_comp, t_mem, t_coll, 1e-12)
    return {
        "arch": report["arch"], "shape": report["shape"],
        "mesh": report["mesh"],
        "t_comp": t_comp, "t_mem": t_mem, "t_coll": t_coll,
        "dominant": dom[1], "useful_frac": frac,
        "useful_flops": useful, "computed_flops": computed,
        "raw_hlo_flops": (report.get("flops_per_device") or 0) * chips,
        "temp_gib": (report["memory"].get("temp_size_in_bytes") or 0) / 2**30,
    }


_FIX_NOTES = {
    "compute": "cut remat/causal waste: triangular attention schedule, "
               "dots remat where memory allows",
    "memory": "raise arithmetic intensity: fuse norms/rope, larger "
              "per-chip batch, bf16 optimizer reads",
    "collective": "overlap or shrink the exchange: shard_map all-to-all "
                  "for MoE, reduce-scatter grads, avoid SP round-trips",
}


def _measured_porc(quick: bool):
    """Measured routing roofline: per-(block, n_bins, scheme) cells on
    the WP trace, three engines each —

      oracle      rank-sequential strict-cap PoRC (``ref_porc_assign``)
      jnp-block   snapshot-probing block engine (``ref_porc_snapshot``)
      pallas      the same block engine as a Pallas kernel with the
                  load vector in VMEM and candidate hashing fused into
                  the probe scan (``kernels.porc_snapshot``)

    Unlike the dry-run table below this always runs, so CI's
    BENCH_results.json carries real routing-roofline rows even when no
    compiled dry-run artifacts are present. On the CI backend (CPU) the
    Pallas rows execute in **interpret mode** — they are a semantics +
    bit-parity signal (``pallas_exact``), *not* a kernel speed number;
    ``pallas_over_block < 1`` is expected there. The compiled column is
    run manually on a TPU VM (``python -m benchmarks.run --quick``
    writes the same rows with ``backend=tpu`` and Mosaic timings).
    """
    import numpy as np

    from repro.kernels import porc_snapshot
    from repro.kernels.blocks import HHPolicy
    from repro.kernels.ref import (ref_porc_assign, ref_porc_route,
                                   ref_porc_snapshot)

    backend = jax.default_backend()
    eps = 0.05
    # the sequential oracle is ~1.2 k msgs/s on CPU — keep M small
    # enough that the measured rows cost seconds, not minutes
    M = 8192 if quick else 65536
    cells = [(512, 1024)] if quick else [(512, 1024), (128, 256)]
    keys = wp_keys(M)
    rows = []
    for block, n_bins in cells:
        t_oracle, _ = time_median(
            lambda: ref_porc_assign(keys, n_bins, block=block, eps=eps))
        t_block, (a_block, _) = time_median(
            lambda: ref_porc_snapshot(keys, n_bins, block=block, eps=eps))
        t_pal, (a_pal, _) = time_median(
            lambda: porc_snapshot(keys, n_bins, block=block, eps=eps))
        exact = bool((np.asarray(a_block) == np.asarray(a_pal)).all())
        record("roofline", scenario="porc_engines", scheme="porc",
               backend=backend, n_msgs=M, n_bins=n_bins, block=block,
               oracle_msgs_per_sec=M / t_oracle,
               block_msgs_per_sec=M / t_block,
               block_over_oracle=t_oracle / t_block,
               pallas_msgs_per_sec=M / t_pal,
               pallas_over_block=t_block / t_pal,
               pallas_over_oracle=t_oracle / t_pal,
               pallas_exact=exact)
        rows.append(["porc", block, n_bins, fmt(M / t_oracle, 0),
                     fmt(M / t_block, 0), fmt(M / t_pal, 0),
                     fmt(t_block / t_pal, 2), exact])
    # W-Choices cell: the HH policy path, where the Pallas kernel also
    # fuses the count-min sketch update + budget lookup into the scan.
    # No sequential oracle exists (probe budgets are sketch-defined).
    block, n_bins = cells[0]
    pol = HHPolicy(scheme="w", width=1024)
    t_w, (a_w, _) = time_median(
        lambda: ref_porc_route(keys, n_bins, block=block, eps=eps,
                               policy=pol))
    t_wp, (a_wp, _) = time_median(
        lambda: ref_porc_route(keys, n_bins, block=block, eps=eps,
                               policy=pol, engine="pallas"))
    exact = bool((np.asarray(a_w) == np.asarray(a_wp)).all())
    record("roofline", scenario="porc_engines", scheme="wchoices",
           backend=backend, n_msgs=M, n_bins=n_bins, block=block,
           block_msgs_per_sec=M / t_w,
           pallas_msgs_per_sec=M / t_wp,
           pallas_over_block=t_w / t_wp,
           pallas_exact=exact)
    rows.append(["wchoices", block, n_bins, "-", fmt(M / t_w, 0),
                 fmt(M / t_wp, 0), fmt(t_w / t_wp, 2), exact])
    mode = "compiled" if backend == "tpu" else "interpret"
    print(table(f"§Roofline — measured PoRC engines (WP trace, "
                f"backend={backend}, pallas={mode})",
                ["scheme", "block", "n_bins", "oracle msg/s",
                 "jnp-block msg/s", "pallas msg/s", "pallas/jnp",
                 "exact"], rows))


def run(quick: bool = False, results_dir: str = "results/dryrun"):
    _measured_porc(quick)
    reps = load_reports(results_dir)
    if not reps:
        print("no dry-run reports found — run "
              "`python -m repro.launch.dryrun --all --out ...` first "
              "(measured PoRC rows above were still recorded)")
        return
    rows = []
    for r in reps:
        a = analyze(r)
        rows.append([a["arch"], a["shape"], a["mesh"],
                     fmt(a["t_comp"] * 1e3, 2), fmt(a["t_mem"] * 1e3, 2),
                     fmt(a["t_coll"] * 1e3, 2), a["dominant"],
                     fmt(a["useful_frac"], 3)])
    print(table("§Roofline — per-cell terms (ms/step per chip, TPU v5e "
                "197TF/819GBs/50GBs)",
                ["arch", "shape", "mesh", "compute", "memory",
                 "collective", "dominant", "useful/roof"], rows))
    print("\nfix-it guide per dominant term:")
    for k, v in _FIX_NOTES.items():
        print(f"  {k:10s}: {v}")


if __name__ == "__main__":
    run()
