"""Multi-host serving on a simulated mesh — the scale-out gate.

Routes a keyed Zipf stream across ``--hosts`` (default 8) simulated
hosts: source lanes live on a 1-D ``("sources",)`` device mesh
(``--xla_force_host_platform_device_count``, the same trick
``launch/dryrun.py`` uses), per-block routing runs under ``shard_map``
and the delta-merge is a ``jax.lax.psum`` (``repro.kernels.mesh``).

Three measurements, all recorded into BENCH_results.json:

* **exactness** — sharded assignment bit-identical to the vmapped
  single-host engine at ``sync_every=1`` (asserted unconditionally;
  this is the acceptance-criteria cell CI gates).
* **throughput** — sharded msgs/sec vs the vmapped single-host engine
  on the same stream. ``--gate`` asserts the ratio: ≥ 1.0 when the
  machine has at least ``hosts`` CPU cores (real parallel headroom),
  else ≥ 0.7 (the partitioning-overhead bound — 8 fake devices on
  fewer cores share the same silicon, so parity is the ceiling, not
  speedup; the measured ratio is printed either way).
* **chaos conservation** — a ``ServingEngine`` on a
  ``MeshCGRequestRouter`` with the async submit path takes a kill-one
  mid-run; ``submitted == served + in_flight`` is asserted at every
  tick and the drain must end with zero in flight, zero dropped.

When the current process has too few devices (the default CI bench job
runs single-device), the whole measurement re-execs as a subprocess
with the device-count flag set — results come back as JSON and are
recorded in the parent's BENCH_results.json.

``--demo`` routes a paper-scale stream (2^21 messages, 8192 bins)
across the mesh and prints per-host lane stats — the §V-C topology at
deployment size.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .common import fmt, record, table, time_median

_MARK = "MULTIHOST_RESULT_JSON:"


def _workload(quick: bool, demo: bool):
    if demo:
        return dict(M=2**21, n_bins=8192, block=2048, chunk=16)
    if quick:
        return dict(M=131072, n_bins=8192, block=2048, chunk=16)
    return dict(M=524288, n_bins=8192, block=2048, chunk=16)


# ---------------------------------------------------------------------------
# In-process measurement (needs len(jax.devices()) >= hosts)
# ---------------------------------------------------------------------------

def _measure(hosts: int, quick: bool, demo: bool) -> list[dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.mesh import mesh_porc_multisource
    from repro.kernels.ref import ref_porc_multisource
    from repro.launch.mesh import enter_mesh, make_source_mesh
    from repro.runtime.chaos import ChaosSchedule
    from repro.serve import MeshCGRequestRouter, ServingEngine

    S = hosts
    mesh = make_source_mesh(hosts)
    w = _workload(quick, demo)
    rng = np.random.default_rng(0)
    keys = jnp.asarray((rng.zipf(1.2, w["M"]) % 100_000).astype(np.int32))
    rows = []

    # -- exactness: the CI-gated sync_every=1 cell (ragged length on
    # purpose: spans + tail must match too)
    ke = keys[: (4096 + 7 if quick else 65536 + 7)]
    a_ref, _ = ref_porc_multisource(ke, w["n_bins"], S, sync_every=1,
                                    block=w["block"], chunk=w["chunk"])
    a_mesh, _ = mesh_porc_multisource(ke, w["n_bins"], mesh, n_sources=S,
                                      sync_every=1, block=w["block"],
                                      chunk=w["chunk"])
    exact = bool(jnp.array_equal(a_ref, a_mesh))
    assert exact, "sharded routing diverged from the single-host engine"
    rows.append(dict(scenario="exactness", hosts=hosts, sync_every=1,
                     n_msgs=int(ke.shape[0]), exact=True))

    # -- throughput: sharded vs vmapped single-host on the same stream
    with enter_mesh(mesh):
        t_mesh, _ = time_median(lambda: mesh_porc_multisource(
            keys, w["n_bins"], mesh, n_sources=S, sync_every=1,
            block=w["block"], chunk=w["chunk"]))
    t_ref, _ = time_median(lambda: ref_porc_multisource(
        keys, w["n_bins"], S, sync_every=1, block=w["block"],
        chunk=w["chunk"]))
    ratio = t_ref / t_mesh
    rows.append(dict(scenario="throughput", hosts=hosts, mode="sharded",
                     n_msgs=w["M"], msgs_per_sec=w["M"] / t_mesh,
                     ratio=ratio, cpu_cores=os.cpu_count()))
    rows.append(dict(scenario="throughput", hosts=hosts, mode="single_host",
                     n_msgs=w["M"], msgs_per_sec=w["M"] / t_ref))

    if demo:
        a, st = mesh_porc_multisource(keys, w["n_bins"], mesh, n_sources=S,
                                      sync_every=1, block=w["block"],
                                      chunk=w["chunk"])
        load = np.asarray(st.base)
        rows.append(dict(scenario="demo", hosts=hosts, n_msgs=w["M"],
                         n_bins=w["n_bins"],
                         imbalance=float(load.max() / load.mean() - 1.0)))

    # -- chaos conservation on the mesh: async submit + kill-one
    n_rep = 8
    router = MeshCGRequestRouter(n_replicas=n_rep, alpha=4, n_sources=S,
                                 mesh=mesh, capacity_weighted=True)
    eng = ServingEngine([lambda b: b for _ in range(n_rep)], router,
                        max_batch=8, async_submit=True,
                        chaos=ChaosSchedule.kill_one(3, at=6),
                        heartbeat_timeout_steps=2)
    ticks = 20 if quick else 40
    for _ in range(ticks):
        kb = (rng.zipf(1.3, 64) % 4096).astype(np.int32)
        eng.submit_batch(kb, [None] * 64)
        eng.step()
        served = sum(r.served for r in eng.replicas)
        assert eng.submitted == served + eng.in_flight, \
            "per-tick conservation violated under chaos"
    for _ in range(500):
        if eng.in_flight == 0:
            break
        eng.step()
    served = sum(r.served for r in eng.replicas)
    assert eng.submitted == served + eng.in_flight
    rows.append(dict(scenario="chaos_kill_one", hosts=hosts,
                     submitted=eng.submitted, served=served,
                     in_flight_end=eng.in_flight, dropped=eng.dropped,
                     retried=eng.retried, evacuations=eng.evacuations))
    return rows


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _via_subprocess(hosts: int, quick: bool, demo: bool) -> list[dict]:
    """Re-exec with the device-count flag (it must be set before jax
    initializes, which in this process it already has)."""
    import repro
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={hosts}"
                        ).strip()
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, "-m", "benchmarks.bench_multihost", "--child",
           "--hosts", str(hosts)]
    if quick:
        cmd.append("--quick")
    if demo:
        cmd.append("--demo")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1800)
    for line in out.stdout.splitlines():
        if not line.startswith(_MARK):
            print(line)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError(f"multihost child failed (rc={out.returncode})")
    payload = [ln for ln in out.stdout.splitlines()
               if ln.startswith(_MARK)]
    if not payload:
        raise RuntimeError("multihost child produced no result payload")
    return json.loads(payload[-1][len(_MARK):])


def run(quick: bool = False, gate: bool = False, demo: bool = False,
        hosts: int = 8, min_ratio: float | None = None):
    import jax
    if len(jax.devices()) >= hosts:
        rows = _measure(hosts, quick, demo)
    else:
        print(f"{len(jax.devices())} device(s) in-process — re-execing "
              f"with {hosts} simulated hosts")
        rows = _via_subprocess(hosts, quick, demo)
    for r in rows:
        record("multihost", **r)

    thr = {r["mode"]: r for r in rows if r.get("scenario") == "throughput"}
    chaos = next(r for r in rows if r["scenario"] == "chaos_kill_one")
    ratio = thr["sharded"]["ratio"]
    cores = thr["sharded"].get("cpu_cores") or 1
    print(table(
        f"multi-host serving on {hosts} simulated hosts",
        ["scenario", "msgs/sec", "ratio", "dropped"],
        [["sharded", fmt(thr["sharded"]["msgs_per_sec"], 0),
          fmt(ratio, 2), "-"],
         ["single_host", fmt(thr["single_host"]["msgs_per_sec"], 0),
          "1.00", "-"],
         ["chaos_kill_one", "-", "-", chaos["dropped"]]]))
    print(f"exactness at sync_every=1: OK; chaos: served "
          f"{chaos['served']}/{chaos['submitted']}, "
          f"retried {chaos['retried']}, dropped {chaos['dropped']}")
    d = next((r for r in rows if r.get("scenario") == "demo"), None)
    if d:
        print(f"demo: {d['n_msgs']:,} msgs over {d['hosts']} hosts, "
              f"{d['n_bins']} bins, imbalance {d['imbalance']:.4f}")
    if gate:
        need = min_ratio if min_ratio is not None else (
            1.0 if cores >= hosts else 0.7)
        assert ratio >= need, (
            f"sharded throughput ratio {ratio:.2f} below the "
            f"{need:.2f} gate ({cores} cores for {hosts} hosts)")
        assert chaos["dropped"] == 0 and chaos["in_flight_end"] == 0
        print(f"gate OK (ratio {ratio:.2f} >= {need:.2f}, zero dropped)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--gate", action="store_true")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--hosts", type=int, default=8)
    ap.add_argument("--min-ratio", type=float, default=None)
    ap.add_argument("--child", action="store_true",
                    help="internal: emit rows as JSON for the parent")
    args = ap.parse_args()
    if args.child:
        rows = _measure(args.hosts, args.quick, args.demo)
        print(_MARK + json.dumps(rows))
        return
    run(quick=args.quick, gate=args.gate, demo=args.demo,
        hosts=args.hosts, min_ratio=args.min_ratio)


if __name__ == "__main__":
    main()
