"""Failure benchmark — latency *during* replica death and migration.

Kill-1-of-8 replicas mid-stream under a hot-key shift, with KV-cache-
like keyed session state (hundreds of KB per request, tens of MB per
virtual replica). The interesting number is not the settled latency
after everything re-converges but the transient while it happens: the
heartbeat detection window, the evacuation (capacity-proportional via
``delegation.evacuate``), and the at-least-once retries of the
stranded queue all show up as served-request latency measured in
engine *steps* (deterministic — no wall-clock flakiness in CI).

Gates (the ISSUE acceptance criteria, asserted in ``run``):

* **zero lost** — submitted == served after drain, nothing in flight,
  ``dropped == 0`` (at-least-once accounting balances);
* **graceful degradation** — settled mean latency ≤ 1.5× the
  pre-failure mean;
* **defaults-off parity** — the failure machinery armed but idle
  (empty chaos schedule, heartbeats on, ramp on) is bit-identical to
  the plain engine: same owner-map trajectory, queue depths and moves.

The byte-budget variant replays the same scenario with
``byte_budget_per_rebalance`` + ``min_gain_per_byte`` on, recording how
migration metering changes bytes moved — informational, compared via
``benchmarks/compare.py``.
"""
from __future__ import annotations

import numpy as np

from repro.runtime.chaos import ChaosSchedule
from repro.serve.engine import CGRequestRouter, ServingEngine

from .common import fmt, record, table

N = 8
MAX_BATCH = 8
LOAD = 48                      # offered req/step (util 0.75 of 8×8)
STATE_BYTES = 256 * 1024.0     # KV-cache-like per-request session state
WINDOW = 25                    # steps per latency measurement window


def _drive(eng, steps, *, seed=0, shift_at=None):
    """Offered load: zipf keys, hot set shifting identity at
    ``shift_at``. Returns per-step cumulative served-latency counts so
    phases can be sliced out afterwards."""
    rng = np.random.default_rng(seed)
    marks = []
    for step in range(steps):
        keys = rng.zipf(1.25, size=LOAD).astype(np.int64) % 4096
        if shift_at is not None and step >= shift_at:
            keys = (keys + 1777) % 4096
        eng.submit_batch(keys.astype(np.int32), list(keys))
        eng.step()
        marks.append(len(eng.latency_steps))
    return marks


def _window_mean(lat, marks, lo_step, hi_step):
    """Mean/p99 latency (steps) of requests *served* in [lo, hi)."""
    lo = marks[lo_step - 1] if lo_step > 0 else 0
    hi = marks[hi_step - 1] if hi_step <= len(marks) else len(lat)
    seg = np.asarray(lat[lo:hi])
    if len(seg) == 0:
        return float("nan"), float("nan")
    return float(seg.mean()), float(np.percentile(seg, 99))


def _scenario(steps, chaos, *, byte_budget=0.0, min_gain=0.0, seed=0):
    router = CGRequestRouter(
        N, capacity_weighted=True, adaptive_moves=True, hysteresis=True,
        state_bytes_per_request=STATE_BYTES,
        byte_budget_per_rebalance=byte_budget,
        min_gain_per_byte=min_gain)
    eng = ServingEngine(
        [lambda b: b for _ in range(N)], router, max_batch=MAX_BATCH,
        chaos=chaos, heartbeat_timeout_steps=2, retry_backoff_steps=1,
        readmit_ramp_steps=20)
    marks = _drive(eng, steps, seed=seed, shift_at=steps // 2)
    # drain everything still in flight so the accounting can balance
    drain = 0
    while eng.in_flight > 0 and drain < 1000:
        eng.step()
        drain += 1
    return eng, router, marks


def _kill_one(steps, kill_at, recover_at):
    eng, router, marks = _scenario(
        steps, ChaosSchedule.kill_one(3, at=kill_at, recover_at=recover_at))
    served = sum(r.served for r in eng.replicas)
    lost = eng.submitted - served - eng.in_flight
    pre, pre99 = _window_mean(eng.latency_steps, marks,
                              kill_at - WINDOW, kill_at)
    dur, dur99 = _window_mean(eng.latency_steps, marks,
                              kill_at, kill_at + WINDOW)
    settled, settled99 = _window_mean(eng.latency_steps, marks,
                                      steps - WINDOW, steps)
    ratio = settled / max(pre, 1e-9)
    rows = [["pre-failure", fmt(pre, 2), fmt(pre99, 1)],
            ["during failure", fmt(dur, 2), fmt(dur99, 1)],
            ["settled", fmt(settled, 2), fmt(settled99, 1)]]
    print(table(f"kill-1-of-{N} at step {kill_at} (recover {recover_at}, "
                "hot-key shift mid-run): served-request latency in steps",
                ["phase", "mean", "p99"], rows))
    print(f"accounting: submitted {eng.submitted} = served {served} + "
          f"in-flight {eng.in_flight} (lost {lost}, retried {eng.retried}, "
          f"dropped {eng.dropped}); evacuations {eng.evacuations}, "
          f"moves {router.moves}, bytes moved "
          f"{router.bytes_moved / 2**20:.1f} MiB")
    record("failures", section="kill_one",
           pre_mean_latency_steps=pre, during_mean_latency_steps=dur,
           during_p99_latency_steps=dur99,
           settled_mean_latency_steps=settled,
           settled_over_pre=ratio, lost=int(lost), retried=eng.retried,
           evacuations=eng.evacuations, moves=router.moves,
           bytes_moved=router.bytes_moved)
    return lost, eng.in_flight, eng.dropped, ratio


def _byte_budget_variant(steps):
    """Migration metering under a *slowdown* (the pure rebalance path —
    no mandatory evacuation): one replica drops to quarter speed and
    the capacity-weighted engine wants to shed its VWs. With per-request
    state accrual the rate/bytes ratio is nearly uniform across VWs, so
    the cost-benefit floor acts as a veto: the metered run refuses to
    drag ~100 MiB of session state for marginal queue relief — the
    arXiv:1610.05121 argument that a migration must amortize its
    transfer before it is worth executing."""
    chaos_at = steps // 4
    rows, out = [], {}
    for name, bb, mg in (("unmetered", 0.0, 0.0),
                         ("metered", 4 * STATE_BYTES, 2e-7)):
        eng, router, marks = _scenario(
            steps, ChaosSchedule.slowdown(0, at=chaos_at, factor=4.0),
            byte_budget=bb, min_gain=mg)
        served = sum(r.served for r in eng.replicas)
        lost = eng.submitted - served - eng.in_flight
        settled, _ = _window_mean(eng.latency_steps, marks,
                                  steps - WINDOW, steps)
        out[name] = (lost, router.bytes_moved)
        record("failures", section="byte_budget", scheme=name,
               bytes_moved=router.bytes_moved, moves=router.moves,
               settled_mean_latency_steps=settled, lost=int(lost))
        rows.append([name, fmt(router.bytes_moved / 2**20, 1),
                     router.moves, fmt(settled, 2), int(lost)])
    print(table("migration metering (byte budget + min gain/byte) under "
                "a 4x slowdown of replica 0",
                ["config", "MiB moved", "moves", "settled lat", "lost"],
                rows))
    return out


def _parity(steps=60):
    """Armed-but-idle failure machinery ≡ plain engine, bit-for-bit."""
    def run(**kw):
        router = CGRequestRouter(N, capacity_weighted=True,
                                 adaptive_moves=True, hysteresis=True)
        eng = ServingEngine([lambda b: b for _ in range(N)], router,
                            max_batch=MAX_BATCH, **kw)
        rng = np.random.default_rng(5)
        traj = []
        for _ in range(steps):
            keys = rng.zipf(1.25, size=LOAD).astype(np.int64) % 4096
            eng.submit_batch(keys.astype(np.int32), list(keys))
            eng.step()
            traj.append((tuple(np.asarray(router.vw_owner)),
                         tuple(eng.queue_depths()), router.moves))
        return traj

    plain = run()
    armed = run(chaos=ChaosSchedule([]), heartbeat_timeout_steps=3,
                retry_backoff_steps=2, readmit_ramp_steps=10)
    return plain == armed


def run(quick: bool = False):
    steps = 90 if quick else 150
    kill_at, recover_at = steps // 3, 2 * steps // 3
    lost, in_flight, dropped, ratio = _kill_one(steps, kill_at, recover_at)
    _byte_budget_variant(steps)
    parity = _parity()
    print(f"gates: lost {lost} (target 0), settled/pre latency "
          f"{ratio:.2f}x (target ≤ 1.5x), defaults-off parity {parity}")
    record("failures", section="gate", lost=int(lost),
           settled_over_pre=ratio, parity=parity)
    assert lost == 0 and in_flight == 0 and dropped == 0, (
        f"at-least-once accounting broken: lost={lost} "
        f"in_flight={in_flight} dropped={dropped}")
    assert ratio <= 1.5, (
        f"settled latency {ratio:.2f}x pre-failure mean (target ≤ 1.5x)")
    assert parity, ("armed-but-idle failure machinery diverged from the "
                    "plain serving engine")


if __name__ == "__main__":
    run(quick=True)
