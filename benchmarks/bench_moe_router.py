"""Beyond paper — the CG technique as an MoE router.

Token drop fraction and expert balance: CG (capacity + overflow
probing) vs standard capacity-bounded top-k, across router skew, at the
two assigned MoE geometries. The printed claim is *gated*: at skew >= 1
CG must drop no more token-slots than top-k and keep expert-load CV no
worse (AssertionError → the bench driver fails), and the per-row records
feed the ci.yml moe_router gate block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import ref_cg_dispatch

from .common import fmt, record, table


def run(quick: bool = False):
    geoms = [("qwen3 128e top8", 128, 8, 16, 4096),
             ("phi3.5 16e top2", 16, 2, 6, 4096)]
    skews = (0.5, 2.0) if quick else (0.0, 0.5, 1.0, 2.0, 4.0)
    rows, failures = [], []
    for name, E, k, D, T in geoms:
        for skew in skews:
            r1, r2 = jax.random.split(jax.random.PRNGKey(int(skew * 10)))
            logits = jax.random.normal(r1, (T, E)) \
                + skew * jax.random.normal(r2, (1, E))
            probs = jax.nn.softmax(logits, -1)
            gates, pref = jax.lax.top_k(probs, D)
            cap = max(1, int(1.25 * T * k / E))
            a_cg, _, _, l_cg = ref_cg_dispatch(
                pref.astype(jnp.int32), gates, n_experts=E, k=k, capacity=cap)
            a_tk, _, _, l_tk = ref_cg_dispatch(
                pref[:, :k].astype(jnp.int32), gates[:, :k], n_experts=E,
                k=k, capacity=cap)
            drop_cg = float((np.asarray(a_cg) < 0).mean())
            drop_tk = float((np.asarray(a_tk) < 0).mean())
            cv_cg = float(np.std(np.asarray(l_cg)) /
                          (np.mean(np.asarray(l_cg)) + 1e-9))
            cv_tk = float(np.std(np.asarray(l_tk)) /
                          (np.mean(np.asarray(l_tk)) + 1e-9))
            record("moe_router", section="sweep", geometry=name, skew=skew,
                   drop_cg=drop_cg, drop_tk=drop_tk, cv_cg=cv_cg,
                   cv_tk=cv_tk)
            if skew >= 1.0:
                if drop_cg > drop_tk + 1e-9:
                    failures.append(f"{name} skew={skew}: CG drop "
                                    f"{drop_cg:.4f} > top-k {drop_tk:.4f}")
                if cv_cg > cv_tk + 1e-9:
                    failures.append(f"{name} skew={skew}: CG load CV "
                                    f"{cv_cg:.4f} > top-k {cv_tk:.4f}")
            rows.append([name, skew, fmt(drop_tk, 3), fmt(drop_cg, 3),
                         fmt(cv_tk, 3), fmt(cv_cg, 3)])
    print(table("CG-MoE router vs capacity-bounded top-k "
                "(drop fraction ↓, expert-load CV ↓)",
                ["geometry", "skew", "drop topk", "drop CG",
                 "loadCV topk", "loadCV CG"], rows))
    if failures:
        raise AssertionError("CG-beats-top-k claim violated: "
                             + "; ".join(failures))
    print("gated claim holds: CG (the paper's overflow probing) drops no "
          "more token-slots and keeps expert load no less flat than "
          "top-k at every skew >= 1 point")


if __name__ == "__main__":
    run()
