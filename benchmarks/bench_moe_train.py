"""End-to-end CG-routed MoE training (ROADMAP: MoE at training scale).

Runs a few hundred real optimizer steps on the dry-run (smoke) mesh for
both assigned MoE geometries, comparing the standard capacity-bounded
top-k router (drops overflow tokens) against the paper's CG router
(overflow probes the token's next-choice experts), each under uniform
and skewed per-expert capacities — the Fig 15 heterogeneous-cluster
story transplanted onto the expert axis. Records tokens dropped,
expert-load CV, median step time and the loss curve per cell.

Gates (``--gate`` / the moe_train CI block):
  * CG drop_frac <= top-k drop_frac at capacity skew >= 1
  * per-expert load never exceeds cap_e (max load/cap_e <= 1 exactly)
  * CG step-time overhead <= 1.15x top-k at the same skew
  * scalar-capacity dispatch bit-identical to the uniform capacities-
    vector path (ref and Pallas kernel)
  * loss finite everywhere and decreasing over the run

  python -m benchmarks.bench_moe_train [--quick] [--gate]
         [--arch phi3.5-moe-42b-a6.6b] [--steps N]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.data import PipelineConfig, ShardedTokenPipeline
from repro.kernels.cg_dispatch import cg_dispatch
from repro.kernels.ref import ref_cg_dispatch
from repro.launch import steps as steps_mod
from repro.launch.mesh import enter_mesh, make_smoke_mesh
from repro.models import model_zoo as zoo

from .common import fmt, record, table

GEOMS = ("phi3.5-moe-42b-a6.6b", "qwen3-moe-235b-a22b")
SKEW = 3.0          # cap_0/cap_{E-1} = 1+SKEW at constant total budget
WARMUP = 3          # steps excluded from the step-time median
OVERHEAD_GATE = 1.15


def _cell_cfg(arch: str, router: str, skew: float):
    # widen the smoke geometry (d 64->128, expert FFN 32->128) so the
    # step is expert-compute-dominated like real training — at d=64 the
    # router is half the step and the overhead gate measures probe
    # latency, not training overhead
    cfg = configs.get_smoke_config(arch)
    return cfg.replace(
        d_model=128, d_head=32,
        moe=dataclasses.replace(cfg.moe, router=router, capacity_skew=skew,
                                d_ff_expert=128))


def _train_cell(arch: str, router: str, skew: float, n_steps: int,
                batch: int = 4, seq: int = 64) -> dict:
    """One (geometry, router, capacity-skew) training run."""
    cfg = _cell_cfg(arch, router, skew)
    mesh = make_smoke_mesh()
    steps_mod.install_act_rules(mesh)
    opt_cfg = optim.AdamWConfig(lr_peak=3e-4,
                                warmup_steps=max(2, n_steps // 10),
                                total_steps=n_steps)
    pipe = ShardedTokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch))
    with enter_mesh(mesh):
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = optim.init(params)
        train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
        losses, drops, loads, times = [], [], [], []
        max_load_frac = 0.0
        for step in range(n_steps):
            b = {"tokens": pipe.global_batch(step)[:batch]}
            t0 = time.time()
            params, opt_state, m = train_step(params, opt_state, b)
            jax.block_until_ready(m["loss"])
            dt = time.time() - t0
            losses.append(float(m["loss"]))
            drops.append(float(m["moe_drop_frac"]))
            max_load_frac = max(max_load_frac,
                                float(m["moe_max_load_frac"]))
            loads.append(np.asarray(m["moe_load"]))
            if step >= WARMUP:
                times.append(dt)
    load = np.mean(np.stack(loads[WARMUP:]), axis=0)
    return {
        "arch": arch, "router": router, "skew": skew, "steps": n_steps,
        "drop_frac": float(np.mean(drops[WARMUP:])),
        "load_cv": float(np.std(load) / (np.mean(load) + 1e-9)),
        "max_load_frac": max_load_frac,
        "step_ms": float(np.median(times) * 1e3),
        "loss_first": losses[0], "loss_final": float(np.mean(losses[-5:])),
        "loss_finite": bool(np.isfinite(losses).all()),
    }


def _scalar_vector_parity() -> bool:
    """Scalar-capacity dispatch must stay bit-identical to the uniform
    capacities-vector path — on the jnp oracle AND the Pallas kernel."""
    T, E, k, D = 256, 16, 2, 6
    r1, r2 = jax.random.split(jax.random.PRNGKey(0))
    probs = jax.nn.softmax(
        jax.random.normal(r1, (T, E)) + 2.0 * jax.random.normal(r2, (1, E)),
        -1)
    gates, pref = jax.lax.top_k(probs, D)
    pref = pref.astype(jnp.int32)
    cap = max(1, int(1.25 * T * k / E))
    caps = jnp.full((E,), cap, jnp.float32)
    for fn in (ref_cg_dispatch, cg_dispatch):
        s = fn(pref, gates, n_experts=E, k=k, capacity=cap)
        v = fn(pref, gates, n_experts=E, k=k, capacities=caps)
        if not all(bool(jnp.array_equal(a, b)) for a, b in zip(s, v)):
            return False
    return True


def run(quick: bool = False, gate: bool = False, arch: str | None = None,
        n_steps: int | None = None):
    n_steps = n_steps or (200 if quick else 400)
    geoms = [arch] if arch else list(GEOMS)
    parity = _scalar_vector_parity()
    record("moe_train", section="parity", exact=parity)
    print(f"scalar-capacity vs uniform-vector dispatch parity: "
          f"{'exact' if parity else 'DIVERGED'}")

    rows, failures = [], []
    if not parity:
        failures.append("scalar-capacity dispatch diverged from the "
                        "uniform capacities-vector path")
    for geom in geoms:
        cells = {}
        for router in ("topk", "cg"):
            for skew in (0.0, SKEW):
                c = _train_cell(geom, router, skew, n_steps)
                cells[(router, skew)] = c
                record("moe_train", section="cell", **c)
                rows.append([geom.split("-")[0], router, skew,
                             fmt(c["drop_frac"], 4), fmt(c["load_cv"], 3),
                             fmt(c["max_load_frac"], 3),
                             fmt(c["step_ms"], 1),
                             fmt(c["loss_first"], 3),
                             fmt(c["loss_final"], 3)])
        for skew in (0.0, SKEW):
            tk, cg = cells[("topk", skew)], cells[("cg", skew)]
            overhead = cg["step_ms"] / max(tk["step_ms"], 1e-9)
            record("moe_train", section="gate", arch=geom, skew=skew,
                   drop_cg=cg["drop_frac"], drop_tk=tk["drop_frac"],
                   cv_cg=cg["load_cv"], cv_tk=tk["load_cv"],
                   overhead=overhead,
                   max_load_frac=max(cg["max_load_frac"],
                                     tk["max_load_frac"]),
                   loss_final_cg=cg["loss_final"],
                   loss_final_tk=tk["loss_final"])
            if skew >= 1.0 and cg["drop_frac"] > tk["drop_frac"] + 1e-9:
                failures.append(
                    f"{geom} skew={skew}: CG drop {cg['drop_frac']:.4f} > "
                    f"top-k {tk['drop_frac']:.4f}")
            if overhead > OVERHEAD_GATE:
                failures.append(
                    f"{geom} skew={skew}: CG step-time overhead "
                    f"{overhead:.2f}x > {OVERHEAD_GATE}x")
            for c in (tk, cg):
                if c["max_load_frac"] > 1.0 + 1e-6:
                    failures.append(
                        f"{geom} {c['router']} skew={skew}: expert load "
                        f"{c['max_load_frac']:.4f}x its capacity (> 1)")
                if not c["loss_finite"]:
                    failures.append(
                        f"{geom} {c['router']} skew={skew}: non-finite loss")
                if c["loss_final"] >= c["loss_first"]:
                    failures.append(
                        f"{geom} {c['router']} skew={skew}: loss did not "
                        f"decrease ({c['loss_first']:.3f} -> "
                        f"{c['loss_final']:.3f})")

    print(table(
        f"MoE train: top-k-drop vs CG-overflow x uniform vs skewed "
        f"capacities ({n_steps} steps, drop/loadCV/step-time/loss)",
        ["geometry", "router", "skew", "drop", "loadCV", "maxload/cap",
         "step ms", "loss0", "lossN"], rows))
    for f in failures:
        print(f"GATE FAIL: {f}")
    if failures and gate:
        raise AssertionError("; ".join(failures))
    if not failures:
        print("gates OK: CG drop <= top-k at skew, load <= cap_e, "
              f"overhead <= {OVERHEAD_GATE}x, scalar parity, loss decreasing")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="fail (nonzero exit) on any gate violation")
    ap.add_argument("--arch", default=None, choices=GEOMS,
                    help="run one geometry only (CI smoke job)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    run(quick=args.quick, gate=args.gate, arch=args.arch,
        n_steps=args.steps)


if __name__ == "__main__":
    main()
