"""Diff two ``BENCH_results.json`` files — the benchmark regression signal.

  python -m benchmarks.compare BASELINE.json CURRENT.json
         [--max-wall-ratio X] [--max-rate-drop X] [--max-imbalance-ratio X]

For every figure the driver recorded it prints the wall-time ratio, and
for every record row present in both files (matched on its non-metric
identity fields) the msgs/sec and normalized-imbalance movement.
Without flags the diff is informational (exit 0); each ``--max-*`` flag
turns the corresponding movement into a hard gate. CI runs the
informational diff against the committed ``benchmarks/baseline_quick.json``
on every PR so perf drift is visible in the log, while the absolute
asserts (block-path speedup, multisource gate) live in the workflow.
"""
from __future__ import annotations

import argparse
import json
import sys

# record fields that are measurements, not identity
_METRICS = {
    "msgs_per_sec", "imbalance", "memory", "wall_s", "speedup",
    "speedup_vs_sequential", "loop_s", "engine_s", "imbalance_loop",
    "imbalance_engine", "imbalance_ratio", "best_speedup", "min_speedup",
    "replication", "b1_exact", "ms1_exact", "error",
    "mean_latency_ms", "max_latency_ms", "mean_latency", "queue_spread",
    "moves", "spike_imbalance", "settled_imbalance",
    "kg_over_cg_mean_latency", "cg_over_kg_throughput", "parity",
    "settle_slots", "post_mean_imbalance", "flaps", "peak_budget",
    "settle_adaptive", "settle_best_static", "flash_flap_ratio",
    "flash_moves_ratio", "alpha10_flap_ratio",
    "repl_bound", "ms_parity",
    "pre_mean_latency_steps", "during_mean_latency_steps",
    "during_p99_latency_steps", "settled_mean_latency_steps",
    "settled_over_pre", "lost", "retried", "evacuations", "bytes_moved",
    "ratio", "exact", "served", "in_flight_end", "dropped", "submitted",
    "cpu_cores", "oracle_msgs_per_sec", "block_msgs_per_sec",
    "block_over_oracle", "pallas_msgs_per_sec", "pallas_over_block",
    "pallas_over_oracle", "pallas_exact",
    "drop_frac", "load_cv", "max_load_frac", "step_ms", "loss_first",
    "loss_final", "loss_finite", "drop_cg", "drop_tk", "cv_cg", "cv_tk",
    "overhead", "loss_final_cg", "loss_final_tk",
}


def _identity(rec: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in rec.items()
                        if k not in _METRICS))


def _index(bench: dict) -> dict:
    out = {}
    for rec in bench.get("records", []):
        out.setdefault(_identity(rec), rec)
    return out


def _fmt_ratio(new, old) -> str:
    if not old:
        return "-"
    return f"{new / old:.2f}x"


def compare(base: dict, cur: dict, max_wall_ratio: float | None,
            max_rate_drop: float | None,
            max_imbalance_ratio: float | None) -> list[str]:
    """Print the diff; return the list of gate violations."""
    violations: list[str] = []
    figures = sorted(set(base["benchmarks"]) | set(cur["benchmarks"]))
    for fig in figures:
        b = base["benchmarks"].get(fig)
        c = cur["benchmarks"].get(fig)
        if b is None or c is None:
            print(f"[{fig}] only in {'current' if b is None else 'baseline'}")
            continue
        wb, wc = b.get("wall_time_s"), c.get("wall_time_s")
        head = f"[{fig}] wall {wb}s -> {wc}s"
        if wb and wc:
            ratio = wc / wb
            head += f" ({ratio:.2f}x)"
            if max_wall_ratio and ratio > max_wall_ratio:
                violations.append(
                    f"{fig}: wall time {ratio:.2f}x > {max_wall_ratio}x")
        print(head)
        bi, ci = _index(b), _index(c)
        matched = sorted(set(bi) & set(ci))
        unmatched = len(set(bi) ^ set(ci))
        for key in matched:
            rb, rc = bi[key], ci[key]
            lines = []
            if "msgs_per_sec" in rb and "msgs_per_sec" in rc:
                rate_ratio = rc["msgs_per_sec"] / max(rb["msgs_per_sec"], 1e-9)
                lines.append(f"rate {_fmt_ratio(rc['msgs_per_sec'], rb['msgs_per_sec'])}")
                if max_rate_drop and rate_ratio < 1.0 / max_rate_drop:
                    violations.append(
                        f"{fig} {dict(key)}: msgs/sec dropped "
                        f"{1 / rate_ratio:.2f}x > {max_rate_drop}x")
            if "imbalance" in rb and "imbalance" in rc:
                lines.append(f"imbalance {rb['imbalance']:.4g} -> "
                             f"{rc['imbalance']:.4g}")
                imb_ratio = rc["imbalance"] / max(rb["imbalance"], 1e-9)
                if max_imbalance_ratio and imb_ratio > max_imbalance_ratio:
                    violations.append(
                        f"{fig} {dict(key)}: imbalance {imb_ratio:.2f}x "
                        f"> {max_imbalance_ratio}x")
            if lines:
                ident = " ".join(f"{k}={v}" for k, v in key)
                print(f"    {ident or '(run)'}: {', '.join(lines)}")
        if unmatched:
            print(f"    ({unmatched} rows without a counterpart skipped)")
    return violations


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--max-wall-ratio", type=float, default=None,
                    help="fail if any figure's wall time grows past this")
    ap.add_argument("--max-rate-drop", type=float, default=None,
                    help="fail if any row's msgs/sec drops past this factor")
    ap.add_argument("--max-imbalance-ratio", type=float, default=None,
                    help="fail if any row's imbalance grows past this")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)
    print(f"baseline: {args.baseline} ({base['meta'].get('device', '?')}, "
          f"quick={base['meta'].get('quick')})")
    print(f"current:  {args.current} ({cur['meta'].get('device', '?')}, "
          f"quick={cur['meta'].get('quick')})")
    violations = compare(base, cur, args.max_wall_ratio, args.max_rate_drop,
                         args.max_imbalance_ratio)
    if violations:
        print("\nREGRESSIONS:")
        for v in violations:
            print(f"  - {v}")
        sys.exit(1)
    print("\nno gated regressions")


if __name__ == "__main__":
    main()
