"""Paper Figs. 7/8 — all schemes (+CG) across 5/10/50/100 workers, WP."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cg, metrics, partitioners as P

from .common import fmt, record, table, wp_keys

SCHEMES = ("KG", "PKG", "POTC", "CH", "PORC", "SG")


def run(m: int = 200_000, quick: bool = False):
    ns = (10, 50) if quick else (5, 10, 50, 100)
    keys = wp_keys(m)
    n_keys = 130_000
    alpha = 10
    rows_i, rows_m = [], []
    for n in ns:
        caps = jnp.ones(n) / n
        vws = n * alpha
        row_i, row_m = [n], [n]
        for s in SCHEMES:
            # paper setup: schemes run over n×alpha virtual-worker bins
            a_vw = P.route(s, keys, vws, eps=0.01)
            a = (a_vw % n).astype(jnp.int32)       # VW → worker (uniform)
            imb = float(metrics.normalized_imbalance(a, caps))
            mem = int(metrics.memory_footprint(a, keys, n, n_keys))
            record("schemes_workers", scheme=s, n_workers=n,
                   imbalance=imb, memory=mem)
            row_i.append(fmt(imb, 3))
            row_m.append(mem)
        # block_size=0: this figure compares CG's *imbalance* against the
        # schemes at eps=0.01, below the block path's staleness floor
        cfgv = cg.CGConfig(n_workers=n, alpha=alpha, eps=0.01,
                           slot_len=10_000, block_size=0)
        res = cg.run(cfgv, keys, jnp.full((n,), 1.25 / n))
        imb_cg = float(metrics.normalized_imbalance(res.assignment, caps))
        mem_cg = int(metrics.memory_footprint(res.assignment, keys, n, n_keys))
        record("schemes_workers", scheme="CG", n_workers=n,
               imbalance=imb_cg, memory=mem_cg)
        row_i.append(fmt(imb_cg, 3))
        row_m.append(mem_cg)
        rows_i.append(row_i)
        rows_m.append(row_m)
    print(table("Fig 7/8a — normalized imbalance vs #workers (WP)",
                ["workers", *SCHEMES, "CG"], rows_i))
    print(table("Fig 7/8b — memory footprint vs #workers (WP)",
                ["workers", *SCHEMES, "CG"], rows_m))
    print("paper-claim check: KG/PKG imbalance grows with n; CH/PoRC/CG "
          "bounded ≈ ε; CG memory < CH; PoTC/SG memory worst")


if __name__ == "__main__":
    run()
