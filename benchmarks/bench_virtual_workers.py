"""Paper Fig. 12 — effect of the virtual-worker count (5…1000).

Heterogeneous cluster y=3, z=5. Too few VWs → can't express capacity
ratios; too many → slow convergence; ~100 best (paper's finding).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cg, streams

from .common import fmt, table, wp_keys


def run(m: int = 300_000, quick: bool = False):
    alphas = (1, 10, 100) if quick else (1, 2, 5, 10, 100)
    # alpha = VWs per worker; paper sweeps total VWs 5..1000 on 10 workers
    n = 10
    if quick:
        m = 150_000
    keys = wp_keys(m)
    caps = jnp.asarray(streams.heterogeneous_capacities(n, 3, 5.0) / 0.8,
                       jnp.float32)
    rows = []
    for a in alphas:
        # runtime block path (block_size=128): dynamics figures are
        # robust to block staleness; precision figures pin block_size=0
        cfgv = cg.CGConfig(n_workers=n, alpha=a, eps=0.01, slot_len=10_000,
                           max_moves_per_slot=8)
        res = cg.run(cfgv, keys, caps)
        imb = np.asarray(res.imbalance)
        rows.append([n * a,
                     fmt(float(imb[:3].mean()), 3),
                     fmt(float(imb[-3:].mean()), 3),
                     fmt(float(np.asarray(res.queue_spread)[-1]), 1),
                     fmt(float(np.asarray(res.latency_spread)[-1]), 1),
                     int(res.moves)])
    print(table("Fig 12 — virtual-worker count sweep (heterogeneous y=3 z=5)",
                ["VWs", "imb(start)", "imb(end)", "queueΔ(end)",
                 "latΔ(end)", "moves"], rows))
    print("paper-claim check: ~10 VWs/worker can't match 5× capacity "
          "ratios (imbalance floor); ≈100/worker converges best; very "
          "large counts converge slower per message")


if __name__ == "__main__":
    run()
