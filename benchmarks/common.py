"""Shared helpers for the benchmark harness.

Besides table formatting, this module is the *results emitter*: the
driver (``benchmarks/run.py``) calls :func:`start_run` once, bench
modules append structured rows via :func:`record`, the driver stamps
per-figure wall time via :func:`note_timing` and finally
:func:`write_results` dumps one ``BENCH_results.json`` that CI archives
as the regression signal (wall time, msgs/sec, imbalance per figure).
Standalone module runs (``python -m benchmarks.bench_x``) skip emission
— every helper is a no-op until ``start_run`` is called.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, partitioners as P, streams

_RUN: dict | None = None


def start_run(meta: dict) -> None:
    """Begin collecting results for one driver invocation."""
    global _RUN
    _RUN = {"meta": dict(meta), "benchmarks": {}}


def record(bench: str, **fields) -> None:
    """Append one structured result row for figure/bench ``bench``."""
    if _RUN is None:
        return
    entry = _RUN["benchmarks"].setdefault(bench, {})
    entry.setdefault("records", []).append(fields)


def note_timing(bench: str, seconds: float) -> None:
    if _RUN is None:
        return
    _RUN["benchmarks"].setdefault(bench, {})["wall_time_s"] = round(seconds, 3)


def write_results(path: str) -> str | None:
    """Dump the collected run to ``path`` (JSON). Returns the path."""
    if _RUN is None:
        return None
    _RUN["meta"]["total_wall_time_s"] = round(
        sum(b.get("wall_time_s", 0.0) for b in _RUN["benchmarks"].values()), 3)
    with open(path, "w") as f:
        json.dump(_RUN, f, indent=1, default=str)
    return path


def table(title: str, headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) + 2
              for i, h in enumerate(headers)]
    out = [f"\n== {title} =="]
    out.append("".join(str(h).rjust(w) for h, w in zip(headers, widths)))
    out.append("".join("-" * w for w in widths))
    for r in rows:
        out.append("".join(str(c).rjust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def fmt(x, nd=4):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x != 0 and (abs(x) >= 1e5 or abs(x) < 1e-3):
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def wp_keys(m: int, seed: int = 0) -> jnp.ndarray:
    return streams.sample_trace(jax.random.PRNGKey(seed), streams.WP_TRACE, m)


def scheme_stats(scheme: str, keys, n_bins: int, n_keys: int, eps: float):
    a = P.route(scheme, keys, n_bins, eps=eps)
    caps = jnp.ones(n_bins) / n_bins
    imb = float(metrics.normalized_imbalance(a, caps))
    mem = int(metrics.memory_footprint(a, keys, n_bins, n_keys))
    return imb, mem


def time_median(f, reps: int = 3):
    """Median wall time over ``reps`` runs (after a compile warmup),
    plus the last output so callers don't rerun the workload."""
    out = f()
    jax.block_until_ready(out)                  # warmup: compile + run
    ts = []
    for _ in range(reps):
        t0 = time.time()
        out = f()
        jax.block_until_ready(out)
        ts.append(time.time() - t0)
    return float(np.median(ts)), out


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
