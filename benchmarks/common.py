"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, partitioners as P, streams


def table(title: str, headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) + 2
              for i, h in enumerate(headers)]
    out = [f"\n== {title} =="]
    out.append("".join(str(h).rjust(w) for h, w in zip(headers, widths)))
    out.append("".join("-" * w for w in widths))
    for r in rows:
        out.append("".join(str(c).rjust(w) for c, w in zip(r, widths)))
    return "\n".join(out)


def fmt(x, nd=4):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x != 0 and (abs(x) >= 1e5 or abs(x) < 1e-3):
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def wp_keys(m: int, seed: int = 0) -> jnp.ndarray:
    return streams.sample_trace(jax.random.PRNGKey(seed), streams.WP_TRACE, m)


def scheme_stats(scheme: str, keys, n_bins: int, n_keys: int, eps: float):
    a = P.route(scheme, keys, n_bins, eps=eps)
    caps = jnp.ones(n_bins) / n_bins
    imb = float(metrics.normalized_imbalance(a, caps))
    mem = int(metrics.memory_footprint(a, keys, n_bins, n_keys))
    return imb, mem


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
