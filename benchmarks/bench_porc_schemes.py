"""Paper Fig. 4 — normalized imbalance & memory for all schemes across
zipf skew and virtual-worker counts (standalone partitioner comparison)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metrics, partitioners as P, streams

from .common import fmt, table

SCHEMES = ("KG", "PKG", "POTC", "CH", "PORC", "SG")


def run(m: int = 50_000, n_keys: int = 10_000, eps: float = 0.01,
        quick: bool = False):
    zs = (0.8, 1.4) if quick else (0.4, 0.8, 1.2, 1.6, 2.0)
    vws = (10, 100) if quick else (10, 100, 1000)
    rows = []
    for z in zs:
        keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), m, n_keys, z)
        for n in vws:
            caps = jnp.ones(n) / n
            row = [z, n]
            for s in SCHEMES:
                a = P.route(s, keys, n, eps=eps)
                row.append(fmt(float(metrics.normalized_imbalance(a, caps)), 3))
            rows.append(row)
    print(table("Fig 4a — normalized imbalance (zipf × #virtual workers)",
                ["z", "VWs", *SCHEMES], rows))

    rows = []
    for z in zs:
        keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), m, n_keys, z)
        uniq = int(jnp.unique(keys).size)
        for n in vws:
            row = [z, n]
            for s in SCHEMES:
                a = P.route(s, keys, n, eps=eps)
                mem = int(metrics.memory_footprint(a, keys, n, n_keys))
                row.append(fmt(mem / uniq, 2))      # replication factor
            rows.append(row)
    print(table("Fig 4b — memory overhead (replication factor = keys stored "
                "/ unique keys)", ["z", "VWs", *SCHEMES], rows))
    print("paper-claim check: PoRC/CH imbalance ≈ eps; PoRC replication "
          "≈ KG(=1.0) ≪ SG/PoTC")


if __name__ == "__main__":
    run()
