"""Paper Fig. 4 — normalized imbalance & memory for all schemes across
zipf skew and virtual-worker counts (standalone partitioner comparison),
plus the block-path throughput gate: the block-parallel PoRC runtime
must (a) be bit-identical to the sequential oracle at block=1 and
(b) route ≥10x more msgs/sec than the oracle while staying inside the
(1+eps) capacity envelope (up to block staleness).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics, partitioners as P, streams
from repro.kernels.ref import ref_porc_snapshot

from .common import fmt, record, table, time_median

SCHEMES = ("KG", "PKG", "POTC", "CH", "PORC", "SG")


def _fig4(m: int, n_keys: int, eps: float, quick: bool):
    zs = (0.8, 1.4) if quick else (0.4, 0.8, 1.2, 1.6, 2.0)
    vws = (10, 100) if quick else (10, 100, 1000)
    rows = []
    for z in zs:
        keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), m, n_keys, z)
        for n in vws:
            caps = jnp.ones(n) / n
            row = [z, n]
            for s in SCHEMES:
                a = P.route(s, keys, n, eps=eps)
                imb = float(metrics.normalized_imbalance(a, caps))
                record("porc_schemes", section="fig4_imbalance", z=z,
                       n_bins=n, scheme=s, imbalance=imb)
                row.append(fmt(imb, 3))
            rows.append(row)
    print(table("Fig 4a — normalized imbalance (zipf × #virtual workers)",
                ["z", "VWs", *SCHEMES], rows))

    rows = []
    for z in zs:
        keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), m, n_keys, z)
        uniq = int(jnp.unique(keys).size)
        for n in vws:
            row = [z, n]
            for s in SCHEMES:
                a = P.route(s, keys, n, eps=eps)
                mem = int(metrics.memory_footprint(a, keys, n, n_keys))
                record("porc_schemes", section="fig4_memory", z=z, n_bins=n,
                       scheme=s, replication=mem / uniq)
                row.append(fmt(mem / uniq, 2))      # replication factor
            rows.append(row)
    print(table("Fig 4b — memory overhead (replication factor = keys stored "
                "/ unique keys)", ["z", "VWs", *SCHEMES], rows))
    print("paper-claim check: PoRC/CH imbalance ≈ eps; PoRC replication "
          "≈ KG(=1.0) ≪ SG/PoTC")


def _block_path_gate(quick: bool):
    """Throughput + exactness gate for the block-parallel fast path."""
    n, eps = 100, 0.05
    m = 65_536 if quick else 262_144
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(0), m, 10_000, 1.2)

    # (a) bit-exactness of the block path at block=1
    short = keys[:4096]
    a_seq = np.asarray(P.power_of_random_choices(short, n, eps=eps))
    a_b1 = np.asarray(
        P.power_of_random_choices_blocked(short, n, eps=eps, block=1))
    exact = bool((a_seq == a_b1).all())
    assert exact, "block path with block=1 diverged from the oracle"

    t_seq, a0 = time_median(lambda: P.power_of_random_choices(keys, n, eps=eps),
                      reps=3)
    seq_rate = m / t_seq
    caps = jnp.ones(n) / n
    imb_seq = float(metrics.normalized_imbalance(a0, caps))
    record("porc_schemes", section="block_throughput", path="sequential",
           block=1, m=m, n_bins=n, eps=eps, msgs_per_sec=seq_rate,
           imbalance=imb_seq, b1_exact=exact)

    rows = [["oracle", fmt(t_seq * 1e3, 1), fmt(seq_rate / 1e6, 2), "1.0",
             fmt(imb_seq, 4)]]
    best = 0.0
    for B in (128, 256, 512):
        tb, (a, load) = time_median(
            lambda: ref_porc_snapshot(keys, n, block=B, eps=eps), reps=10)
        imb = float(metrics.normalized_imbalance(a, caps))
        # capacity envelope up to block staleness (≤ B dupes per bin)
        assert float(load.max()) <= (1 + eps) * m / n + B, \
            f"block={B} breached the (1+eps) envelope"
        rate = m / tb
        best = max(best, rate / seq_rate)
        record("porc_schemes", section="block_throughput", path="block",
               block=B, m=m, n_bins=n, eps=eps, msgs_per_sec=rate,
               imbalance=imb, speedup_vs_sequential=rate / seq_rate)
        rows.append([f"block {B}", fmt(tb * 1e3, 1), fmt(rate / 1e6, 2),
                     fmt(rate / seq_rate, 1), fmt(imb, 4)])
    print(table(f"Block-parallel PoRC vs sequential oracle "
                f"(m={m}, {n} VWs, eps={eps})",
                ["path", "ms", "M msg/s", "speedup", "imbalance"], rows))
    print(f"gate: block=1 bit-identical: {exact}; "
          f"best speedup {best:.1f}x (target ≥ 10x)")
    record("porc_schemes", section="block_throughput_summary",
           best_speedup=best, b1_exact=exact)


def run(m: int = 50_000, n_keys: int = 10_000, eps: float = 0.01,
        quick: bool = False):
    _fig4(m, n_keys, eps, quick)
    _block_path_gate(quick)


if __name__ == "__main__":
    run()
