"""Paper Fig. 6 — ε trade-off: CG interpolates between KG and SG.

10 workers × 10 virtual workers, WP-like trace; imbalance and memory as
ε sweeps. Also reports the inner-scheme extremes (KG/SG at VW level).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cg, metrics

from .common import fmt, record, table, wp_keys


def run(m: int = 200_000, quick: bool = False):
    epss = (0.001, 0.01, 0.1) if quick else (0.0001, 0.001, 0.01, 0.1, 1.0)
    n, alpha = 10, 10
    keys = wp_keys(m)
    n_keys = 130_000
    caps = jnp.full((n,), 1.25 / n)        # homogeneous, ρ = 0.8
    rows = []
    for eps in epss:
        # block_size=0: the ε sweep measures the oracle's (1+ε) bound;
        # block staleness (~block/mean-load) would floor it below ε≈0.02
        cfgv = cg.CGConfig(n_workers=n, alpha=alpha, eps=eps,
                           slot_len=10_000, inner="PORC", block_size=0)
        res = cg.run(cfgv, keys, caps)
        imb = float(metrics.normalized_imbalance(
            res.assignment, jnp.ones(n) / n))
        mem = int(metrics.memory_footprint(res.assignment, keys, n, n_keys))
        record("epsilon", eps=eps, imbalance=imb, memory=mem)
        rows.append([eps, fmt(imb, 4), mem])
    for inner in ("KG", "SG"):
        cfgv = cg.CGConfig(n_workers=n, alpha=alpha, eps=0.01,
                           slot_len=10_000, inner=inner, block_size=0)
        res = cg.run(cfgv, keys, caps)
        imb = float(metrics.normalized_imbalance(
            res.assignment, jnp.ones(n) / n))
        mem = int(metrics.memory_footprint(res.assignment, keys, n, n_keys))
        record("epsilon", inner=inner, imbalance=imb, memory=mem)
        rows.append([f"inner={inner}", fmt(imb, 4), mem])
    print(table("Fig 6 — ε trade-off (CG, 10 workers × 10 VWs, WP)",
                ["eps", "imbalance", "memory(keys)"], rows))
    print("paper-claim check: low ε → low imbalance/high memory; "
          "high ε → KG-like memory; ε=0.01 is the paper's middle ground")


if __name__ == "__main__":
    run()
