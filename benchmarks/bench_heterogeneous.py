"""Heterogeneous-cluster delegation benchmark — Figs 9/10, 12/13, 15.

End-to-end comparison of CG (the shared capacity-weighted delegation
runtime, ``repro.core.delegation``) against the capacity-oblivious
schemes (KG / SG / PKG / flat PoRC straight onto workers) on the
paper's heterogeneity scenarios:

* **static** (Fig 9/10): y=3 of 10 workers are z=5× faster; queue
  spread and latency of the oblivious schemes diverge, CG converges.
* **dynamic** (Fig 12/13): capacities change at ⅓ and ⅔ of the stream;
  CG re-converges after each change — the windowed (EWMA) rates plus
  capacity-proportional budgets re-home VWs within a few slots.
* **deployment** (Fig 15): 24 workers, two cpulimit'ed to 30%, fixed
  per-message cost; the CI **gate** lives here — CG mean latency must
  be ≤ ⅓ of KG's — together with the uniform-capacity **parity** gate:
  the engine with capacity weighting off must reproduce the seed
  pairing reference (``delegation.seed_pairing_reference``) bit-for-bit.
* **flash crowd**: the hot key set shifts identity mid-run; the
  adaptive queue-depth move budget (``adaptive_moves=True``) must
  settle in no more slots than the best static M ∈ {2, 8, 32} while
  hysteresis keeps the signal flap count ≤ ⅓ of the no-hysteresis run.
* **Fig 12 granularity**: at α=10 the per-worker ideal VW count of a
  1×-vs-5× mix sits on the busy/idle integer boundary and the raw
  signals ping-pong; the hysteresis run must flap ≤ ⅓ as often.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (cg, controller, delegation, partitioners as P,
                        simulation, streams)

from .common import fmt, record, table, wp_keys

SLOT = 5_000
N = 10

# the delegation-runtime configuration the figures exercise (knobs
# documented in README "Capacity-weighted delegation runtime")
CG_WEIGHTED = dict(capacity_weighted=True, rate_decay=0.6,
                   fcfs_pairing=True)


def _steady(x, frac=3):
    """Mean over the last 1/frac of a per-slot series (steady state)."""
    a = np.asarray(x)
    return float(a[-max(1, len(a) // frac):].mean())


def _static_assignments(keys, caps, n, alpha, max_moves):
    out = {"KG": P.key_grouping(keys, n),
           "SG": P.shuffle_grouping(keys, n),
           "PKG": P.partial_key_grouping(keys, n),
           # flat PoRC: perfectly balanced *counts*, capacity-oblivious
           "PoRC-flat": P.power_of_random_choices_blocked(keys, n,
                                                          eps=0.01)}
    results = {}
    for name, a in out.items():
        results[name] = simulation.simulate_queues(a, caps, n, SLOT)
    cg_cfgs = {
        "CG": cg.CGConfig(n_workers=n, alpha=alpha, eps=0.01, slot_len=SLOT,
                          max_moves_per_slot=max_moves),
        "CG-W": cg.CGConfig(n_workers=n, alpha=alpha, eps=0.01,
                            slot_len=SLOT, max_moves_per_slot=max_moves,
                            **CG_WEIGHTED),
    }
    moves = {}
    for name, cfgv in cg_cfgs.items():
        res = cg.run(cfgv, keys, caps)
        results[name] = res
        moves[name] = int(res.moves)
    return results, moves


def _fig9_10_static(m: int):
    keys = wp_keys(m)
    caps = jnp.asarray(streams.heterogeneous_capacities(N, 3, 5.0) / 0.8,
                       jnp.float32)
    results, moves = _static_assignments(keys, caps, N, alpha=20,
                                         max_moves=16)
    rows = []
    for name, r in results.items():
        lat = _steady(r.mean_latency)
        imb = _steady(r.imbalance)
        qs = float(np.asarray(r.queue_spread)[-1])
        record("heterogeneous", section="fig9_10_static", scheme=name,
               mean_latency=lat, imbalance=imb, queue_spread=qs,
               moves=moves.get(name))
        rows.append([name, fmt(lat, 1), fmt(imb, 3), fmt(qs, 0),
                     moves.get(name, "-")])
    print(table("Fig 9/10 — static heterogeneous (y=3 z=5, 10 workers): "
                "steady-state mean latency / imbalance / queue spread",
                ["scheme", "mean lat", "imb", "queueΔ", "moves"], rows))
    print("paper-claim check: KG/SG/PKG/flat-PoRC diverge under "
          "heterogeneity; CG converges, and capacity-weighted budgets "
          "(CG-W) converge in a few slots instead of one VW per slot")


def _fig12_13_dynamic(m: int):
    keys = wp_keys(m)
    slots = m // SLOT
    caps = np.zeros((slots, N))
    for start, c in streams.dynamic_capacity_schedule(N, m):
        caps[start // SLOT:] = c / 0.8
    capsj = jnp.asarray(caps, jnp.float32)
    third = slots // 3

    series = {
        "KG": simulation.simulate_queues(P.key_grouping(keys, N), capsj,
                                         N, SLOT).imbalance,
        "SG": simulation.simulate_queues(P.shuffle_grouping(keys, N), capsj,
                                         N, SLOT).imbalance,
    }
    moves = {}
    for name, kw in (("CG", {}), ("CG-W", CG_WEIGHTED)):
        res = cg.run(cg.CGConfig(n_workers=N, alpha=20, eps=0.01,
                                 slot_len=SLOT, max_moves_per_slot=16, **kw),
                     keys, capsj)
        series[name] = res.imbalance
        moves[name] = int(res.moves)

    rows = []
    for name, s in series.items():
        imb = np.asarray(s)
        spike = float(imb[2 * third: 2 * third + 3].mean())
        settled = float(imb[-3:].mean())
        record("heterogeneous", section="fig12_13_dynamic", scheme=name,
               spike_imbalance=spike, settled_imbalance=settled,
               moves=moves.get(name))
        rows.append([name, fmt(float(imb[:3].mean()), 2), fmt(spike, 2),
                     fmt(settled, 2), moves.get(name, "-")])
    print(table("Fig 12/13 — time-varying capacities ((3,5)→(5,4)→(2,10)):"
                " imbalance start / post-change spike / settled",
                ["scheme", "start", "spike", "settled", "moves"], rows))
    print("paper-claim check: CG re-converges after every capacity "
          "change; the windowed-rate capacity-weighted engine settles "
          "lower because budgets track the *new* shares immediately")


def _fig15_deployment(m: int) -> float:
    """Fig 15 gate point: 24 workers, 2 cpulimit'ed to 30%."""
    workers = 24
    keys = streams.sample_trace(jax.random.PRNGKey(0), streams.TW_TRACE, m)
    frac = np.concatenate([[0.3, 0.3], np.ones(workers - 2)])
    fr = jnp.asarray(frac, jnp.float32)
    caps = jnp.asarray(frac / frac.sum() / 0.8, jnp.float32)
    sms = 0.5
    offered = float(frac.sum()) / (sms * 1e-3) * 0.75

    assigns = {"KG": P.key_grouping(keys, workers),
               "SG": P.shuffle_grouping(keys, workers),
               "PKG": P.partial_key_grouping(keys, workers),
               "PoRC-flat": P.power_of_random_choices_blocked(
                   keys, workers, eps=0.01)}
    res_cg = cg.run(cg.CGConfig(n_workers=workers, alpha=20, eps=0.01,
                                slot_len=SLOT, max_moves_per_slot=16,
                                **CG_WEIGHTED), keys, caps)
    assigns["CG-W"] = res_cg.assignment[2 * m // 3:]   # steady state

    rows, res = [], {}
    for name, a in assigns.items():
        r = simulation.simulate_deployment(a, workers, sms, fr,
                                           offered_rate_per_s=offered)
        res[name] = r
        record("heterogeneous", section="fig15_deployment", scheme=name,
               service_ms=sms, msgs_per_sec=float(r.throughput),
               mean_latency_ms=float(r.mean_latency_ms),
               max_latency_ms=float(r.max_latency_ms))
        rows.append([name, fmt(float(r.throughput) / 1000, 1),
                     fmt(float(r.mean_latency_ms), 2),
                     fmt(float(r.max_latency_ms), 2)])
    print(table("Fig 15 — deployment, 2/24 workers cpulimit'ed to 30% "
                f"(svc {sms} ms)", ["scheme", "kq/s", "mean ms", "max ms"],
                rows))
    ratio = float(res["KG"].mean_latency_ms / res["CG-W"].mean_latency_ms)
    thr = float(res["CG-W"].throughput / res["KG"].throughput)
    print(f"gate: KG/CG mean-latency ratio {ratio:.2f}x (target ≥ 3x); "
          f"CG/KG throughput {thr:.2f}x")
    return ratio, thr


def _settle_slots(imb: np.ndarray, tau: float = 0.18, win: int = 3) -> int:
    """Slots until the rolling-``win`` mean imbalance first dips below
    ``tau`` (len(imb) if it never does)."""
    for k in range(len(imb) - win + 1):
        if float(imb[k: k + win].mean()) <= tau:
            return k
    return len(imb)


def _flash_crowd(m: int) -> tuple[int, int, float, float]:
    """Flash crowd: the hot key set shifts identity at m/2. Adaptive
    queue-depth budgets must re-converge in no more slots than the best
    static M without overshooting (fewer total moves than the largest
    static budget), and hysteresis must cut signal flaps to ≤ ⅓."""
    keys = wp_keys(m)
    half = m // 2
    keys = jnp.concatenate(
        [keys[:half], (keys[half:] + 50_000) % streams.WP_TRACE.n_keys])
    caps = jnp.asarray(streams.heterogeneous_capacities(N, 3, 5.0) / 0.8,
                       jnp.float32)
    shift_slot = half // SLOT
    base = dict(n_workers=N, alpha=20, eps=0.01, slot_len=SLOT,
                **CG_WEIGHTED)

    cfgs = {f"static M={M}": cg.CGConfig(max_moves_per_slot=M, **base)
            for M in (2, 8, 32)}
    cfgs["adaptive"] = cg.CGConfig(max_moves_per_slot=32,
                                   adaptive_moves=True, hysteresis=True,
                                   **base)
    cfgs["adaptive (no hyst)"] = cg.CGConfig(max_moves_per_slot=32,
                                             adaptive_moves=True, **base)
    rows, settles, flaps, moves = [], {}, {}, {}
    for name, cfgv in cfgs.items():
        res = cg.run(cfgv, keys, caps)
        post = np.asarray(res.imbalance)[shift_slot:]
        tel = res.telemetry
        settles[name] = _settle_slots(post)
        flaps[name] = int(np.asarray(tel.flaps).sum())
        moves[name] = int(res.moves)
        peak_budget = int(np.asarray(tel.budget)[shift_slot:].max())
        record("heterogeneous", section="flash_crowd", scheme=name,
               settle_slots=settles[name], post_mean_imbalance=float(
                   post.mean()), moves=int(res.moves), flaps=flaps[name],
               peak_budget=peak_budget)
        rows.append([name, settles[name], fmt(float(post.mean()), 3),
                     int(res.moves), flaps[name], peak_budget])
    print(table("Flash crowd — hot-key shift at m/2 (slots to settle "
                "below imb 0.18 / post-shift mean / moves / flaps)",
                ["scheme", "settle", "post imb", "moves", "flaps",
                 "peak budget"], rows))
    best_static = min(v for k, v in settles.items() if k.startswith("static"))
    flap_ratio = flaps["adaptive"] / max(flaps["adaptive (no hyst)"], 1)
    moves_ratio = moves["adaptive"] / max(moves["static M=32"], 1)
    print(f"gate: adaptive settles in {settles['adaptive']} slots vs best "
          f"static {best_static}; hysteresis flap ratio {flap_ratio:.2f}; "
          f"moves vs static M=32 {moves_ratio:.2f} "
          f"(targets: ≤ best static, ≤ 0.33, ≤ 1.0)")
    return settles["adaptive"], best_static, flap_ratio, moves_ratio


def _fig12_alpha10_flaps(m: int) -> float:
    """Fig 12 granularity effect: at α=10 a 1×-vs-5× mix puts the
    per-worker ideal VW count on the busy/idle integer boundary and the
    raw signals ping-pong every slot; hysteresis (enter/exit levels +
    dwell) must cut the flap count to ≤ ⅓ at no settled-imbalance
    cost."""
    keys = wp_keys(m)
    caps = jnp.asarray(streams.heterogeneous_capacities(N, 3, 5.0) / 0.8,
                       jnp.float32)
    base = dict(n_workers=N, alpha=10, eps=0.01, slot_len=SLOT,
                max_moves_per_slot=16, **CG_WEIGHTED)
    rows, flaps = [], {}
    for name, hyst in (("no hysteresis", False), ("hysteresis", True)):
        res = cg.run(cg.CGConfig(hysteresis=hyst, **base), keys, caps)
        imb = np.asarray(res.imbalance)
        flaps[name] = int(np.asarray(res.telemetry.flaps).sum())
        record("heterogeneous", section="fig12_alpha10_flaps", scheme=name,
               flaps=flaps[name], settled_imbalance=float(imb[-5:].mean()),
               moves=int(res.moves))
        rows.append([name, flaps[name], fmt(float(imb[-5:].mean()), 3),
                     int(res.moves)])
    print(table("Fig 12 — α=10 granularity boundary (1×-vs-5× mix): "
                "signal flaps / settled imbalance / moves",
                ["config", "flaps", "settled imb", "moves"], rows))
    ratio = flaps["hysteresis"] / max(flaps["no hysteresis"], 1)
    print(f"gate: flap ratio {ratio:.2f} (target ≤ 0.33)")
    return ratio


def _parity_gate(trials: int = 50) -> bool:
    """Uniform-capacity engine ≡ seed pairing, bit-for-bit, on random
    scenarios (every busy worker owning ≥ 1 VW — the configuration in
    which the seed's burned-slot bug cannot fire)."""
    rng = np.random.default_rng(0)
    for _ in range(trials):
        n = int(rng.integers(2, 12))
        a = int(rng.integers(1, 6))
        V, M = n * a, int(rng.integers(1, 10))
        owner = np.repeat(np.arange(n), a).astype(np.int32)
        rng.shuffle(owner)
        owner[:n] = np.arange(n)                 # everyone owns ≥ 1
        load = (rng.random(V) * 100).astype(np.float32)
        util = (rng.random(n) * 1.6).astype(np.float32)
        exp_owner, exp_done = delegation.seed_pairing_reference(
            n, M, load, owner, util)
        dcfg = delegation.DelegationConfig(n_workers=n, n_virtual=V,
                                           max_moves_per_slot=M)
        st = delegation.init_state(dcfg, vw_owner=jnp.asarray(owner))
        st, moved = delegation.rebalance_step(
            dcfg, st, jnp.asarray(util), jnp.asarray(util > 0.85),
            jnp.asarray(util < 0.75), jnp.asarray(load),
            jnp.ones(n, jnp.float32))
        if not (np.asarray(st.vw_owner) == exp_owner).all():
            return False
        if int(moved) != exp_done:
            return False
        # the adaptive controller with both knobs off must degrade to
        # exactly this path: raw threshold masks, static budget
        ccfg = controller.ControllerConfig(n_workers=n, max_moves=M)
        _, busy, idle, budget = controller.controller_step(
            ccfg, controller.init_controller(ccfg), jnp.asarray(util),
            jnp.asarray(util), 1.0, 0.85, 0.80, 0.75, 0.80)
        st2 = delegation.init_state(dcfg, vw_owner=jnp.asarray(owner))
        st2, moved2 = delegation.rebalance_step(
            dcfg, st2, jnp.asarray(util), busy, idle, jnp.asarray(load),
            jnp.ones(n, jnp.float32), budget)
        if not (np.asarray(st2.vw_owner) == exp_owner).all():
            return False
        if int(moved2) != exp_done:
            return False
    return True


def run(m: int = 300_000, quick: bool = False):
    if quick:
        m = 150_000
    _fig9_10_static(m)
    _fig12_13_dynamic(m)
    ratio, thr = _fig15_deployment(100_000 if quick else 200_000)
    (settle_adaptive, settle_static, flash_flap_ratio,
     flash_moves_ratio) = _flash_crowd(m)
    alpha10_flap_ratio = _fig12_alpha10_flaps(m)
    parity = _parity_gate()
    assert parity, "uniform-capacity engine diverged from the seed pairing"
    assert settle_adaptive <= settle_static, (
        f"adaptive budget settled in {settle_adaptive} slots, slower than "
        f"the best static budget ({settle_static})")
    assert flash_flap_ratio <= 1 / 3, (
        f"flash-crowd hysteresis flap ratio {flash_flap_ratio:.2f} > 1/3")
    assert flash_moves_ratio <= 1.0, (
        f"adaptive budget overshot: {flash_moves_ratio:.2f}x the moves of "
        f"the largest static budget")
    assert alpha10_flap_ratio <= 1 / 3, (
        f"alpha=10 hysteresis flap ratio {alpha10_flap_ratio:.2f} > 1/3")
    record("heterogeneous", section="gate", kg_over_cg_mean_latency=ratio,
           cg_over_kg_throughput=thr, parity=parity,
           settle_adaptive=settle_adaptive, settle_best_static=settle_static,
           flash_flap_ratio=flash_flap_ratio,
           flash_moves_ratio=flash_moves_ratio,
           alpha10_flap_ratio=alpha10_flap_ratio)
    print(f"parity gate: uniform-capacity engine (and the controller with "
          f"both knobs off) ≡ seed pairing over 50 random scenarios: "
          f"{parity}")


if __name__ == "__main__":
    run(quick=True)
