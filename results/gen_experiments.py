"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json. The narrative sections are maintained by hand in
EXPERIMENTS.md around the AUTOGEN markers."""
import glob
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import analyze  # noqa: E402
from repro import configs  # noqa: E402


def load():
    reps = []
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))[0]
        reps.append(r)
    order = {s: i for i, s in enumerate(
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"])}
    reps.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return reps


def dryrun_table(reps):
    lines = ["| arch | shape | mesh | ok | compile s | temp GiB/dev | "
             "args GiB/dev | coll GiB/dev | coll breakdown |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in reps:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL | | | | {r.get('error','')[:60]} |")
            continue
        bk = r["collectives"]["bytes_by_kind"]
        brk = " ".join(f"{k.split('-')[-1][:4]}:{v/2**20:.0f}M"
                       for k, v in sorted(bk.items(), key=lambda kv: -kv[1])
                       if v > 2**20) or "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']} | "
            f"{(r['memory'].get('temp_size_in_bytes') or 0)/2**30:.2f} | "
            f"{(r['memory'].get('argument_size_in_bytes') or 0)/2**30:.2f} | "
            f"{r['collectives']['total_bytes']/2**30:.3f} | {brk} |")
    # skipped cells
    for a, s, ok in configs.cells():
        if not ok:
            lines.append(f"| {a} | {s} | both | **skipped** | | | | | "
                         f"full-attention arch at 500k (DESIGN §4) |")
    return "\n".join(lines)


def roofline_table(reps):
    lines = ["| arch | shape | mesh | compute ms | memory ms | coll ms | "
             "dominant | useful/roof | MODEL/computed |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in reps:
        if not r.get("ok"):
            continue
        a = analyze(r)
        ratio = a["useful_flops"] / max(a["computed_flops"], 1)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{a['t_comp']*1e3:.2f} | {a['t_mem']*1e3:.2f} | "
            f"{a['t_coll']*1e3:.2f} | {a['dominant']} | "
            f"{a['useful_frac']:.3f} | {ratio:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    reps = load()
    open("results/dryrun_table.md", "w").write(dryrun_table(reps))
    open("results/roofline_table.md", "w").write(roofline_table(reps))
    n_ok = sum(1 for r in reps if r.get("ok"))
    print(f"{n_ok}/{len(reps)} cells ok; tables written")
