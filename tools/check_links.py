#!/usr/bin/env python
"""Fail on broken relative links in the repo's markdown docs.

Scans README.md, ROADMAP.md and everything under docs/ for markdown
links/images ``[text](target)`` and verifies that every relative target
(optionally carrying a ``#anchor``) exists on disk, resolved against
the file that contains it. External schemes (http/https/mailto) and
pure in-page anchors are skipped. A small REQUIRED list also pins the
docs CI actually depends on (the tuning + partitioner playbooks) so a
rename can't silently drop them from the scan. Exit code 1 lists every
broken link.

  python tools/check_links.py        # from the repo root (CI does this)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP = ("http://", "https://", "mailto:", "#")

ROOT = Path(__file__).resolve().parent.parent

# docs that must exist AND be scanned — the playbooks other docs,
# benchmarks and CI gate messages point readers at
REQUIRED = ("docs/tuning.md", "docs/partitioners.md",
            "docs/fault_tolerance.md", "docs/multihost.md",
            "docs/moe.md")


def iter_docs():
    for name in ("README.md", "ROADMAP.md"):
        p = ROOT / name
        if p.exists():
            yield p
    yield from sorted((ROOT / "docs").glob("**/*.md"))


def check(path: Path) -> list[str]:
    broken = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            # only targets that escape the repo root are exempt
            # (badge-style ../../ links at the hosting forge); a
            # parent-relative link to a real repo file is still checked
            if not resolved.is_relative_to(ROOT):
                continue
            if not resolved.exists():
                broken.append(f"{path.relative_to(ROOT)}:{lineno}: "
                              f"broken link -> {target}")
    return broken


def main() -> int:
    broken, n_files, seen = [], 0, set()
    for doc in iter_docs():
        n_files += 1
        seen.add(str(doc.relative_to(ROOT)))
        broken.extend(check(doc))
    broken.extend(f"{req}: required doc missing"
                  for req in REQUIRED if req not in seen)
    for b in broken:
        print(b)
    print(f"checked {n_files} markdown files: "
          f"{'FAIL, ' + str(len(broken)) + ' broken' if broken else 'all links OK'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
