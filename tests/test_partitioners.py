import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import stream_len

from repro.core import metrics, partitioners as P, streams

N_KEYS = 2000
M = stream_len(30_000, 20_000)


@pytest.fixture(scope="module")
def zipf_keys():
    return streams.sample_zipf_stream(jax.random.PRNGKey(0), M, N_KEYS, 1.2)


@pytest.mark.parametrize("scheme", P.ALL_SCHEMES)
def test_assignment_in_range(zipf_keys, scheme):
    n = 20
    a = np.asarray(P.route(scheme, zipf_keys, n))
    assert a.shape == (M,)
    assert a.min() >= 0 and a.max() < n


def test_kg_is_per_key_deterministic(zipf_keys):
    a = np.asarray(P.key_grouping(zipf_keys, 16))
    keys = np.asarray(zipf_keys)
    for k in np.unique(keys[:200]):
        assert len(np.unique(a[keys == k])) == 1


def test_sg_perfectly_balanced(zipf_keys):
    n = 16
    a = P.shuffle_grouping(zipf_keys, n)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() - L.min() <= 1


def test_pkg_at_most_two_bins_per_key(zipf_keys):
    a = np.asarray(P.partial_key_grouping(zipf_keys, 16))
    keys = np.asarray(zipf_keys)
    for k in np.unique(keys[:200]):
        assert len(np.unique(a[keys == k])) <= 2


def test_potc_near_perfect_balance(zipf_keys):
    n = 16
    caps = jnp.ones(n) / n
    imb = float(metrics.normalized_imbalance(
        P.power_of_two_choices(zipf_keys, n), caps))
    assert imb < 0.01


@pytest.mark.parametrize("eps", [0.01, 0.05, 0.1])
def test_porc_imbalance_bounded_by_eps(zipf_keys, eps):
    """Paper §VI-A: I(m) ≤ eps·(m/n)."""
    n = 20
    a = P.power_of_random_choices(zipf_keys, n, eps=eps)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() <= (1 + eps) * M / n + 1


def test_ch_load_bounded(zipf_keys):
    n = 20
    eps = 0.05
    a = P.consistent_hashing_bounded(zipf_keys, n, eps=eps)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() <= (1 + eps) * M / n + 1


def test_porc_memory_below_sg_and_ch(zipf_keys):
    """Paper claim: PoRC memory ≈ KG ≪ CH < SG/PoTC."""
    n = 50
    mem = {s: int(metrics.memory_footprint(
        P.route(s, zipf_keys, n, eps=0.05), zipf_keys, n, N_KEYS))
        for s in ("KG", "SG", "PORC", "CH")}
    assert mem["KG"] <= mem["PORC"] <= mem["CH"] <= mem["SG"]


def test_kg_imbalance_grows_with_skew():
    n = 20
    caps = jnp.ones(n) / n
    imbs = []
    for z in (0.4, 1.0, 1.6):
        ks = streams.sample_zipf_stream(jax.random.PRNGKey(1), M, N_KEYS, z)
        imbs.append(float(metrics.normalized_imbalance(
            P.key_grouping(ks, n), caps)))
    assert imbs[0] < imbs[1] < imbs[2]


def test_route_unknown_scheme_raises(zipf_keys):
    with pytest.raises(ValueError):
        P.route("NOPE", zipf_keys, 4)


# ---------------------------------------------------------------------------
# block-parallel variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", P.BLOCKED_SCHEMES)
def test_blocked_b1_bit_identical_to_oracle(zipf_keys, scheme):
    sub = zipf_keys[:5000]
    a_seq = np.asarray(P.route(scheme, sub, 16, eps=0.05))
    a_b1 = np.asarray(P.route(scheme, sub, 16, eps=0.05, block_size=1))
    np.testing.assert_array_equal(a_seq, a_b1)


@pytest.mark.parametrize("scheme", P.BLOCKED_SCHEMES)
@pytest.mark.parametrize("block", [64, 128])
def test_blocked_in_range_any_length(zipf_keys, scheme, block):
    """Blocked variants accept lengths that are not block multiples."""
    sub = zipf_keys[: 3 * block + 17]
    a = np.asarray(P.route(scheme, sub, 16, block_size=block))
    assert a.shape == (len(sub),)
    assert a.min() >= 0 and a.max() < 16


@pytest.mark.parametrize("block", [64, 256])
def test_blocked_porc_envelope(zipf_keys, block):
    """Block staleness never exceeds one block of overshoot per bin."""
    n, eps = 20, 0.05
    a = P.power_of_random_choices_blocked(zipf_keys, n, eps=eps, block=block)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() <= (1 + eps) * M / n + block
    assert L.sum() == M


def test_blocked_potc_balance(zipf_keys):
    """Blocked PoTC stays near-balanced (within block staleness)."""
    n, block = 16, 128
    a = P.power_of_two_choices_blocked(zipf_keys, n, block=block)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() - L.min() <= 2 * block


def test_blocked_pkg_two_bins_per_key(zipf_keys):
    """Key-splitting property survives blocking: ≤ 2 bins per key."""
    a = np.asarray(P.partial_key_grouping_blocked(zipf_keys, 16, block=128))
    keys = np.asarray(zipf_keys)
    for k in np.unique(keys[:200]):
        assert len(np.unique(a[keys == k])) <= 2


# ---------------------------------------------------------------------------
# multi-source variants (§V-C distributed sources)
# ---------------------------------------------------------------------------

def test_multisource_s1_matches_blocked(zipf_keys):
    """route(sources=1) is bit-identical to the blocked single-source
    path — the multisource engine at S=1 is the same semantics."""
    sub = zipf_keys[:5000]
    a_blk = np.asarray(P.route("PORC", sub, 16, eps=0.05, block_size=128))
    a_ms = np.asarray(P.route("PORC", sub, 16, eps=0.05, block_size=128,
                              sources=1))
    np.testing.assert_array_equal(a_blk, a_ms)


@pytest.mark.parametrize("sources", [10, 100])
def test_multisource_route_in_range_any_length(zipf_keys, sources):
    """Multi-source routing accepts lengths not divisible by S·block."""
    sub = zipf_keys[: 2 * 128 * sources // 3 + 7]
    a = np.asarray(P.route("PORC", sub, 16, block_size=128, sources=sources,
                           sync_every=2))
    assert a.shape == (len(sub),)
    assert a.min() >= 0 and a.max() < 16


def test_multisource_porc_envelope(zipf_keys):
    """Total per-bin load stays inside the (1+eps) envelope up to one
    sync window of staleness, even at 50 sources."""
    n, eps, block, sources, sync_every = 20, 0.05, 8, 50, 1
    a = P.power_of_random_choices_multisource(
        zipf_keys, n, sources, eps=eps, block=block, sync_every=sync_every)
    L = np.asarray(metrics.loads(a, n))
    assert L.max() <= (1 + eps) * M / n + sources * sync_every * block + 1
    assert L.sum() == M


def test_multisource_rejects_stateful_non_porc(zipf_keys):
    for scheme in ("PKG", "POTC", "CH"):
        with pytest.raises(ValueError):
            P.route(scheme, zipf_keys[:256], 8, sources=4)
