"""Optimizer, checkpointing, data pipeline, runtime, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import checkpointer as ckpt
from repro.data import PipelineConfig, ShardedTokenPipeline
from repro.runtime import (DelegationBalancer, FTConfig, FaultTolerantRunner,
                           plan_remesh)
from repro.serve import CGRequestRouter, ServingEngine


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = optim.AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.full((4,), 5.0, jnp.bfloat16)}
    state = optim.init(params)
    for _ in range(100):
        g = jax.grad(lambda p: jnp.sum(p["w"].astype(jnp.float32) ** 2))(params)
        params, state, m = optim.update(params, g, state, cfg)
    assert float(jnp.abs(params["w"].astype(jnp.float32)).max()) < 1.0


def test_grad_clipping():
    cfg = optim.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros((3,), jnp.float32)}
    state = optim.init(params)
    huge = {"w": jnp.full((3,), 1e6, jnp.float32)}
    _, _, m = optim.update(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = optim.AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                            total_steps=100)
    lrs = [float(optim.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] >= lrs[3] >= lrs[4]
    assert abs(lrs[2] - 1e-3) < 1e-9


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 10, t)
    out = ckpt.restore(str(tmp_path), 10, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_latest_ignores_tmp(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_00000099.tmp")   # crashed write
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_gc_keeps_max(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, _tree(), max_keep=3)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [3, 4, 5]


def test_async_save(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(3, _tree())
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 2, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 2, bad)


# ---------------------------------------------------------------------------
# data pipeline + straggler/elastic runtime
# ---------------------------------------------------------------------------

def test_pipeline_deterministic():
    cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2)
    p1 = ShardedTokenPipeline(cfg)
    p2 = ShardedTokenPipeline(cfg)
    np.testing.assert_array_equal(np.asarray(p1.global_batch(5)),
                                  np.asarray(p2.global_batch(5)))


def test_shard_move_shifts_share():
    cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=16, n_hosts=2,
                         n_shards_per_host=4)
    p = ShardedTokenPipeline(cfg)
    b0 = p.host_batch(0, 0).shape[0]
    sid = p.move_shard(0, 1)
    assert sid is not None
    assert p.host_batch(0, 0).shape[0] < b0
    assert set(p.shards_of(0)) | set(p.shards_of(1)) == set(range(8))


def test_balancer_pairs_busy_idle():
    cfg = PipelineConfig(vocab=10, seq_len=4, global_batch=8, n_hosts=4)
    pipe = ShardedTokenPipeline(cfg)
    bal = DelegationBalancer(4)
    for _ in range(8):
        bal.observe(0, 2.0)     # straggler
        bal.observe(1, 1.0)
        bal.observe(2, 1.0)
        bal.observe(3, 0.5)     # fast
    moved = bal.rebalance(pipe)
    assert moved == [(0, 3)]
    assert len(pipe.shards_of(0)) == 7 and len(pipe.shards_of(3)) == 9


def test_balancer_fcfs_carryover():
    """A busy host the per-slot move budget could not serve keeps its
    queue position (shared delegation engine FCFS): it is paired next
    slot ahead of newer signals."""
    from repro.runtime import StragglerConfig
    cfg = PipelineConfig(vocab=10, seq_len=4, global_batch=8, n_hosts=4)
    pipe = ShardedTokenPipeline(cfg)
    bal = DelegationBalancer(4, StragglerConfig(max_moves_per_slot=1))
    for _ in range(8):
        bal.observe(0, 3.0)     # worst straggler
        bal.observe(1, 2.0)     # straggler too
        bal.observe(2, 1.0)
        bal.observe(3, 0.5)     # fast
    assert bal.rebalance(pipe) == [(0, 3)]   # budget 1: host 1 carried
    # next slot: host 0 recovered, host 1 unchanged — the carried host 1
    # is served even though its signal is a slot old, and pairs with
    # host 2, which also carried over from the slot-1 idle queue (its
    # relative slowdown put it under θ_i×median then)
    for _ in range(8):
        bal.observe(0, 1.0)
        bal.observe(1, 2.0)
        bal.observe(2, 1.0)
        bal.observe(3, 0.5)
    assert bal.rebalance(pipe) == [(1, 2)]
    assert bal.moves == [(0, 3), (1, 2)]


def test_failure_repairs_shards(tmp_path):
    cfg = PipelineConfig(vocab=10, seq_len=4, global_batch=8, n_hosts=3)
    pipe = ShardedTokenPipeline(cfg)
    runner = FaultTolerantRunner(FTConfig(ckpt_dir=str(tmp_path)),
                                 n_hosts=3, pipeline=pipe)
    moved = runner.on_failure(1)
    assert len(moved) == 8                      # all of host 1's shards
    assert len(pipe.shards_of(1)) == 0
    assert len(pipe.shards_of(0)) + len(pipe.shards_of(2)) == 24


def test_restore_latest_roundtrip(tmp_path):
    runner = FaultTolerantRunner(FTConfig(ckpt_dir=str(tmp_path),
                                          ckpt_every=1), n_hosts=1)
    tree = _tree()
    assert runner.maybe_save(0, tree)
    runner.saver.wait()
    step, restored = runner.restore_latest(jax.tree.map(np.asarray, tree))
    assert step == 0 and restored is not None


def test_plan_remesh():
    assert plan_remesh(256) == (16, 16)
    assert plan_remesh(240) == (15, 16)         # one host of 16 chips lost
    assert plan_remesh(8) == (1, 16)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_serves_all_and_rebalances():
    served_by = [0, 0, 0]

    def mk(i, delay=0):
        def fn(batch):
            served_by[i] += len(batch)
        return fn

    eng = ServingEngine([mk(0), mk(1), mk(2)],
                        CGRequestRouter(3, alpha=4, max_queue=16))
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.5, 300) % 50).astype(np.int32)
    eng.submit_batch(keys, list(range(300)))
    total = 0
    for _ in range(100):
        total += eng.step()
        if total >= 300:
            break
    assert total == 300
    assert sum(served_by) == 300
    assert min(served_by) > 0                    # skew got spread


def test_router_porc_single_matches_stream():
    r = CGRequestRouter(4, alpha=4, eps=0.05)
    outs = [r.route(k) for k in [1, 1, 1, 1, 2, 3, 1, 1]]
    assert all(0 <= o < 4 for o in outs)
    assert r.vw_load.sum() == 8
