"""cg_dispatch Pallas kernel vs oracle + MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cg_dispatch import cg_dispatch
from repro.kernels.ref import ref_cg_dispatch


def _routing(T, E, D, skew, seed=0):
    r1, r2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(r1, (T, E)) + skew * jax.random.normal(
        r2, (1, E))
    probs = jax.nn.softmax(logits, -1)
    gates, pref = jax.lax.top_k(probs, D)
    return pref.astype(jnp.int32), gates


@pytest.mark.parametrize("T,E,k,D", [(256, 8, 1, 4), (512, 16, 2, 6),
                                     (1024, 128, 8, 16), (128, 4, 2, 4)])
def test_kernel_matches_ref(T, E, k, D):
    pref, gates = _routing(T, E, D, skew=2.0)
    cap = max(1, int(1.25 * T * k / E))
    ref = ref_cg_dispatch(pref, gates, n_experts=E, k=k, capacity=cap)
    ker = cg_dispatch(pref, gates, n_experts=E, k=k, capacity=cap)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("skew", [0.0, 2.0, 5.0])
def test_invariants(skew):
    T, E, k, D = 512, 16, 2, 8
    pref, gates = _routing(T, E, D, skew)
    cap = max(1, int(1.25 * T * k / E))
    assign, slot, wts, load = [np.asarray(x) for x in ref_cg_dispatch(
        pref, gates, n_experts=E, k=k, capacity=cap)]
    # 1. no expert exceeds capacity
    assert load.max() <= cap
    # 2. (expert, slot) pairs unique — no buffer collisions
    valid = assign >= 0
    pairs = assign[valid] * 10_000 + slot[valid]
    assert len(np.unique(pairs)) == valid.sum()
    # 3. weights normalized over placed slots
    w = wts.sum(-1)
    has = valid.any(-1)
    np.testing.assert_allclose(w[has], 1.0, atol=1e-5)
    # 4. slots within range
    assert valid.sum() == load.sum()
    assert (slot[valid] >= 0).all() and (slot[valid] < cap).all()


def test_cg_places_more_than_topk_under_skew():
    """The paper's claim in MoE form: overflow probing (CG) strictly
    reduces token dropping vs capacity-bounded top-k."""
    T, E, k = 512, 16, 2
    pref, gates = _routing(T, E, 8, skew=4.0, seed=3)
    cap = max(1, int(1.25 * T * k / E))
    cg_assign, _, _, _ = ref_cg_dispatch(pref, gates, n_experts=E, k=k,
                                         capacity=cap)
    tk_assign, _, _, _ = ref_cg_dispatch(pref[:, :k], gates[:, :k],
                                         n_experts=E, k=k, capacity=cap)
    placed_cg = int((np.asarray(cg_assign) >= 0).sum())
    placed_tk = int((np.asarray(tk_assign) >= 0).sum())
    assert placed_cg > placed_tk


def test_no_skew_equals_topk():
    """With uniform routing and ample capacity, CG == top-k choices."""
    T, E, k = 256, 8, 2
    pref, gates = _routing(T, E, 6, skew=0.0, seed=7)
    cap = T  # unbounded
    assign, _, wts, _ = ref_cg_dispatch(pref, gates, n_experts=E, k=k,
                                        capacity=cap)
    np.testing.assert_array_equal(np.asarray(assign), np.asarray(pref[:, :k]))
