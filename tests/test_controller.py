"""The adaptive delegation controller (repro.core.controller):
queue-depth move budgets, busy/idle hysteresis, static degradation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import stream_len

from repro.core import cg, controller as C, delegation as D, streams

M = stream_len(200_000, 100_000)


def _step(cfg, st, pressure, depths, unit=1.0,
          levels=(0.85, 0.80, 0.75, 0.80)):
    return C.controller_step(cfg, st, jnp.asarray(pressure, jnp.float32),
                             jnp.asarray(depths, jnp.float32), unit,
                             *levels)


# ---------------------------------------------------------------------------
# adaptive budget
# ---------------------------------------------------------------------------

def test_budget_clamped_to_bounds_property():
    """Property: the adaptive budget never leaves
    [min_moves, max_moves], for any pressure/depth/unit stream."""
    rng = np.random.default_rng(0)
    for _ in range(30):
        n = int(rng.integers(2, 16))
        lo = int(rng.integers(1, 4))
        hi = lo + int(rng.integers(0, 32))
        cfg = C.ControllerConfig(n_workers=n, adaptive_moves=True,
                                 min_moves=lo, max_moves=hi,
                                 depth_decay=float(rng.random()),
                                 hysteresis=bool(rng.integers(2)))
        st = C.init_controller(cfg)
        for _ in range(10):
            scale = 10.0 ** rng.integers(-2, 6)
            st, busy, idle, budget = _step(
                cfg, st, rng.random(n) * 2, rng.random(n) * scale,
                unit=float(10.0 ** rng.integers(-3, 3)))
            assert lo <= int(budget) <= hi
            assert not np.any(np.asarray(busy) & np.asarray(idle))


def test_budget_follows_queue_depth():
    cfg = C.ControllerConfig(n_workers=4, adaptive_moves=True,
                             min_moves=1, max_moves=16, depth_decay=0.0)
    st = C.init_controller(cfg)
    # no backlog → floor
    st, _, _, b = _step(cfg, st, np.zeros(4), np.zeros(4))
    assert int(b) == 1
    # uniform backlog: no worker is above the fleet mean → floor
    st, _, _, b = _step(cfg, st, np.zeros(4), np.full(4, 100.0))
    assert int(b) == 1
    # one worker 8 units above the mean → ceil(excess/unit) moves
    st, _, _, b = _step(cfg, st, np.zeros(4), np.array([8.0, 0, 0, 0]))
    assert int(b) == 6            # excess = 8 - 2 = 6, unit = 1
    # huge skewed backlog → ceiling
    st, _, _, b = _step(cfg, st, np.zeros(4), np.array([1e5, 0, 0, 0]))
    assert int(b) == 16


def test_budget_ewma_smooths_spikes():
    """depth_decay keeps one noisy slot from slamming the budget open
    and lets it decay back over ≈1/(1-decay) slots."""
    cfg = C.ControllerConfig(n_workers=2, adaptive_moves=True,
                             min_moves=1, max_moves=32, depth_decay=0.5)
    st = C.init_controller(cfg)
    st, _, _, b0 = _step(cfg, st, np.zeros(2), np.array([40.0, 0.0]))
    st, _, _, b1 = _step(cfg, st, np.zeros(2), np.zeros(2))
    st, _, _, b2 = _step(cfg, st, np.zeros(2), np.zeros(2))
    assert int(b0) == 10          # (1-decay)·20 excess
    assert int(b1) == 5 and int(b2) == 3      # decaying, not pinned
    assert int(b0) < 20           # EWMA halves the instantaneous excess


def test_byte_budget_caps_emitted_moves():
    """With byte_budget set and unit_bytes supplied, the emitted budget
    is floor(byte_budget/unit_bytes), never below 1, and unaffected
    when either side of the knob is absent."""
    cfg = C.ControllerConfig(n_workers=4, adaptive_moves=True,
                             min_moves=1, max_moves=16, depth_decay=0.0,
                             byte_budget=300.0)
    st = C.init_controller(cfg)
    depths = np.array([1e5, 0, 0, 0])       # demand slams to the ceiling
    st, _, _, b = C.controller_step(cfg, st, jnp.zeros(4), depths, 1.0,
                                    0.85, 0.80, 0.75, 0.80, 100.0)
    assert int(b) == 3                      # 300 bytes / 100 per move
    st, _, _, b = C.controller_step(cfg, st, jnp.zeros(4), depths, 1.0,
                                    0.85, 0.80, 0.75, 0.80, 1e6)
    assert int(b) == 1                      # starved budget floors at 1
    st, _, _, b = C.controller_step(cfg, st, jnp.zeros(4), depths, 1.0,
                                    0.85, 0.80, 0.75, 0.80, None)
    assert int(b) == 16                     # no unit_bytes → move-count only
    cfg0 = cfg._replace(byte_budget=0.0)
    st0 = C.init_controller(cfg0)
    _, _, _, b = C.controller_step(cfg0, st0, jnp.zeros(4), depths, 1.0,
                                   0.85, 0.80, 0.75, 0.80, 100.0)
    assert int(b) == 16                     # knob off → unmetered


def test_rebalance_respects_runtime_budget():
    """The engine executes at most ``budget`` moves even when the
    static ceiling and the eligible pairs allow more."""
    n, a = 4, 8
    V = n * a
    owner = np.repeat(np.arange(n), a).astype(np.int32)
    util = np.array([2.0, 0.5, 0.5, 0.5], np.float32)
    dcfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                              max_moves_per_slot=8, capacity_weighted=True)
    caps = np.array([0.3, 1.0, 1.0, 1.0], np.float32)
    st = D.init_state(dcfg, vw_owner=jnp.asarray(owner))
    _, moved_free = D.rebalance_step(
        dcfg, st, jnp.asarray(util), jnp.asarray(util > 0.85),
        jnp.asarray(util < 0.75), jnp.ones(V, jnp.float32),
        jnp.asarray(caps))
    assert int(moved_free) > 2    # capacity-weighted budget wants several
    st2 = D.init_state(dcfg, vw_owner=jnp.asarray(owner))
    _, moved_capped = D.rebalance_step(
        dcfg, st2, jnp.asarray(util), jnp.asarray(util > 0.85),
        jnp.asarray(util < 0.75), jnp.ones(V, jnp.float32),
        jnp.asarray(caps), jnp.int32(2))
    assert int(moved_capped) == 2


def test_static_mode_bit_identical_to_raw_path():
    """With both knobs off the controller degrades to the static
    engine exactly: raw threshold masks, budget == max_moves — the
    seed-parity argument extended through the controller."""
    rng = np.random.default_rng(5)
    for _ in range(25):
        n = int(rng.integers(2, 10))
        a = int(rng.integers(1, 5))
        V, mmax = n * a, int(rng.integers(1, 9))
        owner = np.repeat(np.arange(n), a).astype(np.int32)
        rng.shuffle(owner)
        load = (rng.random(V) * 50).astype(np.float32)
        util = (rng.random(n) * 1.6).astype(np.float32)
        ccfg = C.ControllerConfig(n_workers=n, max_moves=mmax)
        _, busy, idle, budget = _step(ccfg, C.init_controller(ccfg),
                                      util, util)
        np.testing.assert_array_equal(np.asarray(busy), util > 0.85)
        np.testing.assert_array_equal(np.asarray(idle), util < 0.75)
        assert int(budget) == mmax
        dcfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                                  max_moves_per_slot=mmax)
        st_a = D.init_state(dcfg, vw_owner=jnp.asarray(owner))
        st_a, moved_a = D.rebalance_step(
            dcfg, st_a, jnp.asarray(util), jnp.asarray(util > 0.85),
            jnp.asarray(util < 0.75), jnp.asarray(load),
            jnp.ones(n, jnp.float32))
        st_b = D.init_state(dcfg, vw_owner=jnp.asarray(owner))
        st_b, moved_b = D.rebalance_step(
            dcfg, st_b, jnp.asarray(util), busy, idle, jnp.asarray(load),
            jnp.ones(n, jnp.float32), budget)
        np.testing.assert_array_equal(np.asarray(st_a.vw_owner),
                                      np.asarray(st_b.vw_owner))
        assert int(moved_a) == int(moved_b)


# ---------------------------------------------------------------------------
# hysteresis
# ---------------------------------------------------------------------------

def test_dwell_delays_entry():
    cfg = C.ControllerConfig(n_workers=1, hysteresis=True, dwell=3)
    st = C.init_controller(cfg)
    hot = np.array([0.9], np.float32)
    for k in range(2):
        st, busy, _, _ = _step(cfg, st, hot, hot)
        assert not bool(busy[0]), f"latched after {k+1} < dwell slots"
    st, busy, _, _ = _step(cfg, st, hot, hot)
    assert bool(busy[0])
    # one cool slot resets the dwell counter
    st, busy, _, _ = _step(cfg, st, np.array([0.5], np.float32), hot)
    st, busy, _, _ = _step(cfg, st, hot, hot)
    assert not bool(busy[0])


def test_exit_level_latches_between_thresholds():
    """Busy enters above 0.85, exits only below 0.80: a worker
    oscillating in (0.80, 0.85) stays latched instead of flapping."""
    cfg = C.ControllerConfig(n_workers=1, hysteresis=True, dwell=1)
    st = C.init_controller(cfg)
    st, busy, _, _ = _step(cfg, st, np.array([0.9]), np.zeros(1))
    assert bool(busy[0])
    for p in (0.84, 0.81, 0.83, 0.84):
        st, busy, _, _ = _step(cfg, st, np.array([p]), np.zeros(1))
        assert bool(busy[0]), f"unlatched at pressure {p} > exit 0.80"
    st, busy, _, _ = _step(cfg, st, np.array([0.79]), np.zeros(1))
    assert not bool(busy[0])
    assert int(st.flaps) == 2     # one enter + one exit, not 6


def test_no_hysteresis_flaps_at_boundary():
    """The same oscillation without hysteresis flips every slot — the
    flap counter shows the raw ping-pong the latches remove."""
    cfg = C.ControllerConfig(n_workers=1, hysteresis=False)
    st = C.init_controller(cfg)
    for p in (0.9, 0.8, 0.9, 0.8, 0.9, 0.8):
        st, _, _, _ = _step(cfg, st, np.array([p]), np.zeros(1))
    assert int(st.flaps) == 6


def test_cg_alpha10_hysteresis_regression():
    """The Fig-12 granularity scenario: α=10 on a 1×-vs-5× mix puts
    the ideal VW count on the busy/idle integer boundary. With
    hysteresis the signal flap count must drop to ≤ ⅓ of the raw run
    while settling no worse (regression for the ping-pong fix)."""
    keys = streams.sample_trace(jax.random.PRNGKey(0), streams.WP_TRACE, M)
    caps = jnp.asarray(streams.heterogeneous_capacities(10, 3, 5.0) / 0.8,
                       jnp.float32)
    base = dict(n_workers=10, alpha=10, eps=0.01, slot_len=5_000,
                max_moves_per_slot=16, capacity_weighted=True,
                rate_decay=0.6, fcfs_pairing=True)
    flaps, settled = {}, {}
    for hyst in (False, True):
        res = cg.run(cg.CGConfig(hysteresis=hyst, **base), keys, caps)
        flaps[hyst] = int(np.asarray(res.telemetry.flaps).sum())
        settled[hyst] = float(np.asarray(res.imbalance)[-5:].mean())
    assert flaps[False] >= 3 * flaps[True], (
        f"hysteresis flaps {flaps[True]} not ≤ 1/3 of raw {flaps[False]}")
    assert settled[True] <= settled[False] * 1.5, (
        f"hysteresis settled imbalance degraded: {settled}")


# ---------------------------------------------------------------------------
# cg-level telemetry + adaptive budget
# ---------------------------------------------------------------------------

def test_cg_adaptive_budget_bounded_and_telemetry():
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), 60_000,
                                      5_000, 1.2)
    caps = jnp.asarray(streams.heterogeneous_capacities(8, 2, 4.0) / 0.8,
                       jnp.float32)
    cfg = cg.CGConfig(n_workers=8, alpha=10, eps=0.01, slot_len=5_000,
                      max_moves_per_slot=12, adaptive_moves=True,
                      min_moves=2, hysteresis=True, capacity_weighted=True,
                      rate_decay=0.6, fcfs_pairing=True)
    res = cg.run(cfg, keys, caps)
    tel = res.telemetry
    budget = np.asarray(tel.budget)
    executed = np.asarray(tel.executed)
    assert budget.shape == executed.shape == (12,)
    assert (budget >= 2).all() and (budget <= 12).all()
    assert (executed <= budget).all()
    assert int(np.asarray(tel.executed).sum()) == int(res.moves)
    assert np.asarray(tel.queue_depth).shape == (12, 8)
    assert (np.asarray(tel.flaps) >= 0).all()


def test_cg_default_telemetry_static_budget():
    """With the controller off the telemetry still records: budget is
    pinned at the static ceiling and flaps count the raw signals."""
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), 30_000,
                                      5_000, 1.2)
    caps = jnp.ones(5, jnp.float32) / 4.0
    res = cg.run(cg.CGConfig(n_workers=5, alpha=10, slot_len=5_000,
                             max_moves_per_slot=7), keys, caps)
    assert (np.asarray(res.telemetry.budget) == 7).all()


# ---------------------------------------------------------------------------
# serve + straggler integration
# ---------------------------------------------------------------------------

def test_serve_adaptive_router_rebalances_and_bounds_budget():
    from repro.serve.engine import CGRequestRouter
    rng = np.random.default_rng(2)
    r = CGRequestRouter(n_replicas=6, alpha=8, capacity_weighted=True,
                        adaptive_moves=True, hysteresis=True, dwell=2,
                        max_moves_per_rebalance=6)
    assert r.controller_active
    for _ in range(12):
        r.route_batch(rng.integers(0, 500, 256).astype(np.int32))
        occ = rng.random(6).astype(np.float32)
        occ[0] = 0.95                    # replica 0 persistently hot
        occ[1:] = occ[1:] * 0.3          # the rest idle
        depths = occ * 256
        r.rebalance([], [], pressure=occ, depths=depths,
                    capacities=np.ones(6))
        assert 1 <= r.last_budget <= 6
    counts = np.bincount(r.vw_owner, minlength=6)
    assert counts.sum() == 48            # population conserved
    assert counts[0] < 8                 # the hot replica shed VWs
    assert r.moves > 0
    assert r.flap_count >= 2             # enter events are counted


def test_serve_engine_ticks_controller_every_step():
    from repro.serve.engine import CGRequestRouter, ServingEngine
    calls = []
    eng = ServingEngine([lambda b: calls.append(len(b)) for _ in range(3)],
                        router=CGRequestRouter(n_replicas=3, alpha=4,
                                               hysteresis=True),
                        max_batch=4)
    rng = np.random.default_rng(0)
    for _ in range(6):
        eng.submit_batch(rng.integers(0, 50, 16).astype(np.int32),
                         [None] * 16)
        eng.step()
    # the controller saw every tick even when no raw signal fired
    assert int(eng.router._controller.state.flaps) >= 0
    assert sum(eng.queue_depths()) >= 0


def test_straggler_hysteresis_stops_boundary_flapping():
    from repro.runtime.straggler import DelegationBalancer, StragglerConfig

    class _Pipe:
        def __init__(self):
            self.moved = []

        def move_shard(self, src, dst):
            self.moved.append((src, dst))
            return len(self.moved)

    def drive(cfg):
        bal = DelegationBalancer(n_hosts=4, cfg=cfg)
        pipe = _Pipe()
        rng = np.random.default_rng(3)
        for t in range(24):
            # host 0 oscillates just across the busy threshold while
            # host 3 is genuinely idle; hosts 1-2 sit at the median
            wobble = 1.20 if t % 2 == 0 else 1.10
            for h, s in enumerate([wobble, 1.0, 1.0, 0.7]):
                bal.observe(h, s + rng.normal(0, 1e-3))
            bal.rebalance(pipe)
        return bal

    flappy = drive(StragglerConfig(window=1))
    calm = drive(StragglerConfig(window=1, hysteresis=True, dwell=2))
    # the raw signals pair the wobbling host every other slot; the
    # dwell filter sees it never stays busy two slots running and
    # suppresses the churn entirely
    assert len(flappy.moves) >= 8
    assert len(calm.moves) <= 2
    assert calm.flap_count <= 4


def test_straggler_adaptive_budget_scales_with_excess():
    from repro.runtime.straggler import DelegationBalancer, StragglerConfig

    class _Pipe:
        def move_shard(self, src, dst):
            return 1

    bal = DelegationBalancer(
        n_hosts=6, cfg=StragglerConfig(window=1, adaptive_moves=True,
                                       hysteresis=True, dwell=1,
                                       max_moves_per_slot=4))
    pipe = _Pipe()
    for _ in range(3):
        for h, s in enumerate([4.0, 1.0, 1.0, 1.0, 0.5, 0.5]):
            bal.observe(h, s)
        bal.rebalance(pipe)
    # straggler at 4x the median: the summed ratio excess opens the
    # budget past one move per slot but never past the ceiling
    assert 1 <= bal._controller.last_budget <= 4
    assert bal._controller.last_budget > 1


# ---------------------------------------------------------------------------
# per-worker budgets
# ---------------------------------------------------------------------------

def test_per_worker_budget_vector_follows_each_workers_excess():
    """per_worker_budget emits an [n] vector: the flooded worker's own
    excess opens its budget, workers at the mean stay at 0, latched
    busy workers keep the min_moves pacing floor, and the telemetry
    scalar records the effective total."""
    cfg = C.ControllerConfig(n_workers=4, adaptive_moves=True,
                             per_worker_budget=True, min_moves=1,
                             max_moves=8, depth_decay=0.0)
    st = C.init_controller(cfg)
    st, busy, _, b = _step(cfg, st, [0.9, 0.1, 0.1, 0.1],
                           [100.0, 0.0, 0.0, 0.0], unit=10.0)
    assert b.shape == (4,)
    assert int(b[0]) == 8                       # 75 backlog / 10 → clip 8
    assert [int(x) for x in b[1:]] == [0, 0, 0]
    assert int(st.budget) == 8                  # scalar telemetry
    # a busy worker with no excess still gets the min_moves floor
    st2 = C.init_controller(cfg)
    st2, busy2, _, b2 = _step(cfg, st2, [0.9, 0.9, 0.1, 0.1],
                              [100.0, 0.0, 0.0, 0.0], unit=10.0)
    assert bool(busy2[1]) and int(b2[1]) == cfg.min_moves


def test_per_worker_budget_caps_sheds_in_delegation():
    """An [n] budget caps each worker's shed count individually; a
    budget-0 busy worker moves nothing but keeps its FCFS position."""
    n, a = 4, 4
    V = n * a
    dcfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                              max_moves_per_slot=8, fcfs=True)
    st = D.init_state(dcfg)
    util = jnp.array([0.95, 0.9, 0.1, 0.1], jnp.float32)
    busy = jnp.array([True, True, False, False])
    idle = jnp.array([False, False, True, True])
    bud = jnp.array([1, 0, 0, 0], jnp.int32)
    st2, moved = D.rebalance_step(dcfg, st, util, busy, idle,
                                  jnp.ones(V, jnp.float32), jnp.ones(n),
                                  budget=bud)
    assert int(moved) == 1
    assert int((np.asarray(st2.vw_owner)
                != np.asarray(st.vw_owner)).sum()) == 1
    # worker 1 (budget 0) moved nothing and is still queued busy
    assert np.asarray(st2.vw_owner)[np.asarray(st.vw_owner) == 1].tolist() \
        == [1] * a
    assert int(st2.queues.busy_since[1]) != D.NOT_QUEUED
    assert int(st2.queues.busy_since[0]) == D.NOT_QUEUED  # fully served
    # a vector of max_moves is the same as no budget at all
    st3, m3 = D.rebalance_step(dcfg, st, util, busy, idle,
                               jnp.ones(V, jnp.float32), jnp.ones(n))
    st4, m4 = D.rebalance_step(dcfg, st, util, busy, idle,
                               jnp.ones(V, jnp.float32), jnp.ones(n),
                               budget=jnp.full((n,), 8, jnp.int32))
    assert int(m3) == int(m4)
    np.testing.assert_array_equal(np.asarray(st3.vw_owner),
                                  np.asarray(st4.vw_owner))


def test_per_worker_budget_router_wiring():
    """The serving router threads the vector budget end to end, and
    rejects the knob without adaptive_moves (it would be inert)."""
    from repro.serve import CGRequestRouter
    with pytest.raises(ValueError):
        CGRequestRouter(4, adaptive_moves=False, per_worker_budgets=True)
    r = CGRequestRouter(4, alpha=8, adaptive_moves=True,
                        per_worker_budgets=True, capacity_weighted=True)
    rng = np.random.default_rng(0)
    r.route_batch((rng.zipf(1.3, 4096) % 512).astype(np.int32))
    occ = np.array([0.95, 0.1, 0.3, 0.3], np.float32)
    moved = r.rebalance([0], [1], pressure=occ,
                        depths=occ * r.max_queue)
    assert moved >= 1
    assert isinstance(r.last_budget, int)
    assert np.bincount(r.vw_owner, minlength=4).sum() == r.n_virtual
