"""ssd_scan Pallas kernel vs exact sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ref_ssd_scan
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked


def _inputs(B, L, H, P, G, N, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, L, G, N)) / np.sqrt(N)).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, L, G, N)) / np.sqrt(N)).astype(dtype)
    return x, dt, A, Bm, Cm


def _relerr(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / (np.abs(a).max() + 1e-9)


@pytest.mark.parametrize("B,L,H,P,G,N,Q", [
    (2, 128, 4, 32, 1, 64, 32),
    (1, 256, 8, 64, 2, 128, 64),
    (1, 256, 6, 16, 3, 32, 128),
])
def test_kernel_matches_sequential(B, L, H, P, G, N, Q):
    x, dt, A, Bm, Cm = _inputs(B, L, H, P, G, N)
    y_ref = ref_ssd_scan(x, dt, A, Bm, Cm)
    y_k = ssd_scan(x, dt, A, Bm, Cm, chunk=Q)
    assert _relerr(y_ref, y_k) < 1e-4


@pytest.mark.parametrize("Q", [16, 32, 64, 128])
def test_chunk_invariance(Q):
    x, dt, A, Bm, Cm = _inputs(1, 128, 4, 16, 1, 32)
    y128 = ssd_scan(x, dt, A, Bm, Cm, chunk=128)
    yq = ssd_scan(x, dt, A, Bm, Cm, chunk=Q)
    assert _relerr(y128, yq) < 1e-4


def test_bf16_tolerance():
    x, dt, A, Bm, Cm = _inputs(1, 128, 4, 32, 1, 64, dtype=jnp.bfloat16)
    y_ref = ref_ssd_scan(x, dt, A, Bm, Cm)
    y_k = ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    assert _relerr(y_ref, y_k) < 3e-2


def test_jnp_chunked_matches_kernel():
    """The in-model XLA path and the Pallas kernel agree exactly-ish."""
    x, dt, A, Bm, Cm = _inputs(2, 128, 4, 32, 1, 64, seed=3)
    y_jnp = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y_k = ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    assert _relerr(y_jnp, y_k) < 1e-5


def test_decay_only_state_passing():
    """With C ≡ 0 the output must be exactly the D-skip-free zero."""
    x, dt, A, Bm, Cm = _inputs(1, 64, 2, 8, 1, 16)
    y = ssd_scan(x, dt, A, Bm, jnp.zeros_like(Cm), chunk=16)
    assert float(jnp.abs(y).max()) == 0.0
