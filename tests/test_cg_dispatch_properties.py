"""Dispatch-kernel battery: Pallas vs oracle parity + slot invariants.

Sweeps the Pallas ``cg_dispatch`` (interpret mode on CPU — the same
kernel body the TPU path compiles) against ``ref_cg_dispatch`` across
E x k x capacity x block, on both the scalar-capacity and the
per-expert ``capacities [E]`` paths, and pins the heterogeneous-capacity
slot invariants the layer's inverse-permutation dispatch relies on.
Hypothesis cases ride along when the library is installed; the
parametrized sweep runs everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cg_dispatch import cg_dispatch
from repro.kernels.ref import ref_cg_dispatch

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYP = True
except ImportError:        # plain sweep still runs without hypothesis
    HAS_HYP = False


def _routing(T, E, D, skew, seed=0):
    r1, r2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(r1, (T, E)) + skew * jax.random.normal(
        r2, (1, E))
    gates, pref = jax.lax.top_k(jax.nn.softmax(logits, -1), D)
    return pref.astype(jnp.int32), gates


def _skewed_caps(E, base, ratio=4.0):
    w = [ratio ** (-i / max(E - 1, 1)) for i in range(E)]
    s = sum(w)
    return tuple(max(1, int(round(E * base * wi / s))) for wi in w)


# ---------------------------------------------------------------- parity

@pytest.mark.parametrize("E,k,cf,block", [
    (4, 1, 1.0, 64), (8, 2, 1.25, 128), (16, 2, 1.25, 64),
    (16, 4, 1.5, 128), (32, 2, 1.1, 256), (64, 8, 1.25, 128),
])
def test_pallas_matches_ref_scalar(E, k, cf, block):
    T, D = 512, min(E, k + 4)
    pref, gates = _routing(T, E, D, skew=2.0, seed=E + k)
    cap = max(1, int(cf * T * k / E))
    ref = ref_cg_dispatch(pref, gates, n_experts=E, k=k, capacity=cap,
                          block=block)
    ker = cg_dispatch(pref, gates, n_experts=E, k=k, capacity=cap,
                      block=block)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("E,k,block", [(8, 2, 64), (16, 2, 128),
                                       (16, 4, 64), (32, 8, 128)])
def test_pallas_matches_ref_capacities_vector(E, k, block):
    """Heterogeneous per-expert capacities: kernel == oracle exactly."""
    T = 512
    pref, gates = _routing(T, E, min(E, k + 4), skew=3.0, seed=11 * E + k)
    caps = jnp.asarray(_skewed_caps(E, max(1, int(1.25 * T * k / E))),
                       jnp.float32)
    ref = ref_cg_dispatch(pref, gates, n_experts=E, k=k, capacities=caps,
                          block=block)
    ker = cg_dispatch(pref, gates, n_experts=E, k=k, capacities=caps,
                      block=block)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("fn", [ref_cg_dispatch, cg_dispatch],
                         ids=["ref", "pallas"])
@pytest.mark.parametrize("E,k", [(8, 1), (16, 2), (32, 4)])
def test_scalar_equals_uniform_vector(fn, E, k):
    """capacity=C must be bit-identical to capacities=full(E, C) — the
    gate that keeps the pre-vector scalar path un-regressed."""
    T = 384
    pref, gates = _routing(T, E, min(E, k + 4), skew=2.5, seed=E * k)
    cap = max(1, int(1.25 * T * k / E))
    s = fn(pref, gates, n_experts=E, k=k, capacity=cap)
    v = fn(pref, gates, n_experts=E, k=k,
           capacities=jnp.full((E,), cap, jnp.float32))
    for a, b in zip(s, v):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fn", [ref_cg_dispatch, cg_dispatch],
                         ids=["ref", "pallas"])
def test_exactly_one_capacity_arg(fn):
    pref, gates = _routing(256, 8, 4, skew=0.0)
    with pytest.raises(ValueError):
        fn(pref, gates, n_experts=8, k=2)
    with pytest.raises(ValueError):
        fn(pref, gates, n_experts=8, k=2, capacity=16,
           capacities=jnp.full((8,), 16.0))


# ------------------------------------------------------------ invariants

def _check_invariants(assign, slot, wts, load, caps):
    assign, slot, wts, load = map(np.asarray, (assign, slot, wts, load))
    caps = np.asarray(caps)
    E = len(caps)
    valid = assign >= 0
    # per-expert load bounded by its own capacity
    np.testing.assert_array_less(load - 1e-9, caps + 1e-9)
    # load == histogram of non-dropped assignments
    hist = np.bincount(assign[valid], minlength=E).astype(load.dtype)
    np.testing.assert_array_equal(load, hist)
    # (expert, slot) pairs unique, slot < cap_e of its own expert
    pairs = assign[valid] * 1_000_000 + slot[valid]
    assert len(np.unique(pairs)) == valid.sum()
    assert (slot[valid] >= 0).all()
    assert (slot[valid] < caps[assign[valid]]).all()
    # dropped slots carry zero combine weight
    assert (wts[~valid] == 0).all()
    # weights renormalize: == 1 where any slot placed, 0 where all dropped
    wsum = wts.sum(-1)
    has = valid.any(-1)
    np.testing.assert_allclose(wsum[has], 1.0, atol=1e-5)
    np.testing.assert_allclose(wsum[~has], 0.0, atol=1e-7)


@pytest.mark.parametrize("fn", [ref_cg_dispatch, cg_dispatch],
                         ids=["ref", "pallas"])
@pytest.mark.parametrize("skew", [0.0, 2.0, 5.0])
def test_invariants_heterogeneous_caps(fn, skew):
    T, E, k = 512, 16, 2
    pref, gates = _routing(T, E, 8, skew, seed=int(skew * 7))
    caps = _skewed_caps(E, max(1, int(1.25 * T * k / E)))
    out = fn(pref, gates, n_experts=E, k=k,
             capacities=jnp.asarray(caps, jnp.float32))
    _check_invariants(*out, caps=caps)


def test_tiny_capacity_floor():
    """cap_e = 1 everywhere: at most one slot per expert, rest dropped."""
    T, E, k = 128, 8, 2
    pref, gates = _routing(T, E, 6, skew=1.0, seed=5)
    out = ref_cg_dispatch(pref, gates, n_experts=E, k=k,
                          capacities=jnp.ones((E,), jnp.float32))
    _check_invariants(*out, caps=(1,) * E)
    assert np.asarray(out[3]).sum() <= E


def test_starved_expert_sheds_to_next_preference():
    """An expert with cap 0-ish (=1) under heavy demand: overflow probes
    place its spill on later preferences instead of dropping it all."""
    T, E, k = 256, 8, 1
    pref, gates = _routing(T, E, 6, skew=4.0, seed=9)
    caps_uni = (max(1, int(1.25 * T * k / E)),) * E
    hot = int(np.bincount(np.asarray(pref[:, 0]), minlength=E).argmax())
    caps = list(caps_uni)
    caps[hot] = 1
    a_starved = np.asarray(ref_cg_dispatch(
        pref, gates, n_experts=E, k=k,
        capacities=jnp.asarray(caps, jnp.float32))[0])
    a_trunc = np.asarray(ref_cg_dispatch(
        pref[:, :k], gates[:, :k], n_experts=E, k=k,
        capacities=jnp.asarray(caps, jnp.float32))[0])
    assert (a_starved >= 0).sum() > (a_trunc >= 0).sum()


# -------------------------------------------------- hypothesis (optional)

if HAS_HYP:
    SETTINGS = dict(max_examples=15, deadline=None)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.floats(1.0, 8.0))
    @settings(**SETTINGS)
    def test_hyp_invariants_random_skewed_caps(seed, k, ratio):
        T, E = 256, 8
        pref, gates = _routing(T, E, 6, skew=2.0, seed=seed % 10_000)
        caps = _skewed_caps(E, max(1, int(1.25 * T * k / E)), ratio=ratio)
        out = ref_cg_dispatch(pref, gates, n_experts=E, k=k,
                              capacities=jnp.asarray(caps, jnp.float32))
        _check_invariants(*out, caps=caps)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 3))
    @settings(**SETTINGS)
    def test_hyp_scalar_vector_parity_random(seed, k):
        T, E = 192, 8
        pref, gates = _routing(T, E, 6, skew=3.0, seed=seed % 10_000)
        cap = max(1, int(1.25 * T * k / E))
        s = ref_cg_dispatch(pref, gates, n_experts=E, k=k, capacity=cap)
        v = ref_cg_dispatch(pref, gates, n_experts=E, k=k,
                            capacities=jnp.full((E,), cap, jnp.float32))
        for a, b in zip(s, v):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
