"""Small-mesh lower+compile of the production step builders.

The full 512-device sweep runs via repro.launch.dryrun (results in
EXPERIMENTS.md); this test proves the same machinery works end-to-end
on the local device so CI catches sharding-rule regressions fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.launch import steps
from repro.launch.mesh import enter_mesh, make_smoke_mesh
from repro.models import model_zoo as zoo

ARCHS = ["gemma3-1b", "qwen3-moe-235b-a22b", "mamba2-130m", "whisper-small"]


def _batch_specs(cfg, B=2, S=32):
    tok = jnp.int32
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), tok)}
    if cfg.family == "vlm":
        return {"patches": jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.vision_dim), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), tok)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), tok)}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_compiles(arch):
    cfg = configs.get_smoke_config(arch)
    mesh = make_smoke_mesh()
    with enter_mesh(mesh):
        jit_for, p_sh, o_sh = steps.jit_train_step(cfg, mesh)
        pspecs = zoo.param_specs(cfg)
        ospecs = jax.eval_shape(optim.init, pspecs)
        batch = _batch_specs(cfg)
        compiled = jit_for(batch).lower(pspecs, ospecs, batch).compile()
        assert compiled.cost_analysis() is not None


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_compiles(arch):
    cfg = configs.get_smoke_config(arch)
    mesh = make_smoke_mesh()
    with enter_mesh(mesh):
        jit_for, p_sh = steps.jit_serve_step(cfg, mesh)
        pspecs = zoo.param_specs(cfg)
        cache = zoo.cache_spec(cfg, 2, 32)
        tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
        compiled = jit_for(cache, tok).lower(pspecs, cache, tok).compile()
        assert compiled is not None


def test_prefill_step_compiles():
    cfg = configs.get_smoke_config("internlm2-20b")
    mesh = make_smoke_mesh()
    with enter_mesh(mesh):
        jit_for, _ = steps.jit_prefill_step(cfg, mesh)
        pspecs = zoo.param_specs(cfg)
        batch = _batch_specs(cfg)
        compiled = jit_for(batch).lower(pspecs, batch).compile()
        assert compiled is not None


@pytest.mark.slow
def test_train_executes_and_checkpoints(tmp_path):
    """Tiny end-to-end: the real train driver, 6 steps + resume."""
    from repro.launch.train import train
    losses = train("mamba2-130m", n_steps=6, batch=4, seq=32, smoke=True,
                   ckpt_dir=str(tmp_path), ckpt_every=2, n_hosts=2)
    assert len(losses) == 6 and np.isfinite(losses).all()
    losses2 = train("mamba2-130m", n_steps=8, batch=4, seq=32, smoke=True,
                    ckpt_dir=str(tmp_path), resume=True, n_hosts=2)
    assert len(losses2) <= 8     # resumed from a later step


@pytest.mark.slow
def test_train_survives_host_failure(tmp_path):
    from repro.launch.train import train
    losses = train("gemma3-1b", n_steps=6, batch=4, seq=32, smoke=True,
                   ckpt_dir=str(tmp_path), n_hosts=3, fail_host_at=3)
    assert len(losses) == 6 and np.isfinite(losses).all()
