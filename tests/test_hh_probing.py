"""Heavy-hitter-aware probing (D/W-Choices): sketch correctness, the
neutral-policy parity gate, budget/replication bounds, and the wiring
through partitioners, CG and the serving router."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cg
from repro.core import partitioners as P
from repro.core.streams import sample_zipf_stream
from repro.kernels.ref import (HHPolicy, MultiSourcePorcState,
                               hh_sketch_init, hh_sketch_query,
                               hh_sketch_update, multisource_state_init,
                               neutral_hh_policy, porc_state_init,
                               ref_porc_multisource, ref_porc_route)


def zipf_keys(m, z=1.6, n_keys=50_000, seed=0):
    return sample_zipf_stream(jax.random.PRNGKey(seed), m, n_keys, z)


# ---------------------------------------------------------------------------
# count-min sketch
# ---------------------------------------------------------------------------

def test_sketch_never_underestimates():
    pol = HHPolicy(width=512)
    keys = zipf_keys(8192, z=1.2)
    counts = hh_sketch_update(pol, hh_sketch_init(pol), keys)
    uniq, true = np.unique(np.asarray(keys), return_counts=True)
    est = np.asarray(hh_sketch_query(pol, counts, jnp.asarray(uniq)))
    assert (est >= true).all()                  # CMS one-sided error
    assert counts.sum() == pol.depth * 8192     # every row counts all mass


def test_sketch_topk_recall_zipf():
    """The heads of a zipf stream are always classified heavy: estimates
    overshoot by at most m/width per row (CMS bound), far below the head
    counts at default width."""
    pol = HHPolicy()            # width 4096
    keys = zipf_keys(65536, z=1.4)
    counts = hh_sketch_update(pol, hh_sketch_init(pol), keys)
    uniq, true = np.unique(np.asarray(keys), return_counts=True)
    top = uniq[np.argsort(true)[-10:]]
    est = np.asarray(hh_sketch_query(pol, counts, jnp.asarray(top)))
    true_top = np.sort(true)[-10:]
    assert (est >= true_top).all()
    assert (est <= true_top + 4 * 65536 / pol.width).all()


def test_sketch_weighted_update_masks():
    pol = HHPolicy(width=256)
    keys = jnp.asarray([3, 3, 7, 9], jnp.int32)
    w = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    counts = hh_sketch_update(pol, hh_sketch_init(pol), keys, weights=w)
    assert float(counts.sum()) == pol.depth * 3.0
    assert float(hh_sketch_query(pol, counts, jnp.asarray([7]))[0]) <= 1.0


def test_sketch_merge_linearity():
    """CMS is linear: sharded updates summed == one-shot update — the
    property that makes the multisource delta-merge exact."""
    pol = HHPolicy(width=1024)
    keys = zipf_keys(4096, z=1.0)
    whole = hh_sketch_update(pol, hh_sketch_init(pol), keys)
    parts = sum(hh_sketch_update(pol, hh_sketch_init(pol), keys[s::4])
                for s in range(4))
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(parts))


# ---------------------------------------------------------------------------
# neutral-policy bit-parity (the CI gate's test twin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [64, 128])
def test_neutral_policy_bit_parity(block):
    n = 64
    keys = zipf_keys(16384)
    plain, st_p = ref_porc_route(keys, n, block=block)
    neut, st_n = ref_porc_route(keys, n, block=block,
                                policy=neutral_hh_policy(n))
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(neut))
    np.testing.assert_array_equal(np.asarray(st_p.load), np.asarray(st_n.load))
    assert st_p.sketch is None and st_n.sketch is not None


def test_neutral_policy_bit_parity_multisource():
    n, S = 64, 4
    keys = zipf_keys(16128)        # exercises the ragged sub-S tail too
    plain, _ = ref_porc_multisource(keys, n, S, sync_every=2, block=64)
    neut, st = ref_porc_multisource(keys, n, S, sync_every=2, block=64,
                                    policy=neutral_hh_policy(n))
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(neut))
    # the sketch still counted every message while routing identically
    assert float(st.sketch_base.sum() + st.sketch_delta.sum()) == 4 * 16128


def test_policy_none_state_has_no_sketch():
    keys = zipf_keys(4096)
    _, st = ref_porc_route(keys, 32, block=128)
    assert st.sketch is None
    assert porc_state_init(32).sketch is None
    ms = multisource_state_init(32, 2)
    assert ms.sketch_base is None and ms.sketch_delta is None


# ---------------------------------------------------------------------------
# budgets and replication bounds
# ---------------------------------------------------------------------------

def test_tail_budget_bounds_replication():
    """hot_fraction >= 1 turns every key into a tail key: each key's
    replication is capped at d_tail even under heavy skew."""
    n = 64
    keys = zipf_keys(32768, z=1.8)
    pol = HHPolicy(scheme="d", hot_fraction=2.0, d_tail=2)
    a, _ = ref_porc_route(keys, n, policy=pol)
    k, b = np.asarray(keys), np.asarray(a)
    for key in np.unique(k):
        assert len(np.unique(b[k == key])) <= 2


def test_heavy_keys_spread_wider_than_tail():
    n = 200
    keys = zipf_keys(65536, z=1.8)
    pol = HHPolicy(scheme="w")
    a, st = ref_porc_route(keys, n, policy=pol)
    k, b = np.asarray(keys), np.asarray(a)
    uniq, counts = np.unique(k, return_counts=True)
    head = uniq[np.argmax(counts)]
    spread_head = len(np.unique(b[k == head]))
    assert spread_head > pol.d_tail            # heavy keys got more choices
    # tail keys (single occurrence) sit on one bin
    singles = uniq[counts == 1]
    assert all(len(np.unique(b[k == s])) == 1 for s in singles[:50])


def test_w_choices_beats_porc_on_skew():
    """The headline property: under skew, W-Choices cuts replication
    while holding (here: improving) imbalance vs plain PoRC."""
    from repro.core.metrics import memory_footprint
    n, m = 200, 131072
    keys = zipf_keys(m, z=1.6, n_keys=65536, seed=3)
    uniq = len(np.unique(np.asarray(keys)))

    def run(policy):
        a, _ = ref_porc_route(keys, n, policy=policy)
        load = np.bincount(np.asarray(a), minlength=n)
        imb = (load.max() - load.mean()) / load.mean()
        repl = float(memory_footprint(a, keys, n, 65536)) / uniq
        return imb, repl

    imb_p, repl_p = run(None)
    imb_w, repl_w = run(HHPolicy(scheme="w"))
    assert repl_w < repl_p
    assert imb_w <= imb_p + 0.05


# ---------------------------------------------------------------------------
# state carry and the multisource sketch merge path
# ---------------------------------------------------------------------------

def test_policy_state_carry_split_equals_whole():
    n = 64
    keys = zipf_keys(16384)
    pol = HHPolicy(scheme="w")
    whole, st_w = ref_porc_route(keys, n, policy=pol)
    a1, st1 = ref_porc_route(keys[:8192], n, policy=pol)
    a2, st2 = ref_porc_route(keys[8192:], n, policy=pol, state=st1)
    np.testing.assert_array_equal(
        np.asarray(whole),
        np.concatenate([np.asarray(a1), np.asarray(a2)]))
    np.testing.assert_array_equal(np.asarray(st_w.sketch),
                                  np.asarray(st2.sketch))


def test_multisource_sketch_merge_exact_s1():
    """S=1 multisource with policy == single-source with policy, sketch
    included (the delta-merge path is exact at S=1)."""
    n = 64
    keys = zipf_keys(16384)
    pol = HHPolicy(scheme="w")
    a_single, st_s = ref_porc_route(keys, n, policy=pol)
    a_multi, st_m = ref_porc_multisource(keys, n, 1, sync_every=1,
                                         block=128, policy=pol)
    np.testing.assert_array_equal(np.asarray(a_single), np.asarray(a_multi))
    np.testing.assert_array_equal(
        np.asarray(st_s.sketch),
        np.asarray(st_m.sketch_base + st_m.sketch_delta.sum(0)))


def test_multisource_sketch_mass_conserved():
    """Across S sources and sync periods the merged sketch counts every
    routed message exactly (f32 integer sums stay exact here)."""
    n, S, m = 64, 4, 16128
    keys = zipf_keys(m)
    pol = HHPolicy(scheme="d")
    _, st = ref_porc_multisource(keys, n, S, sync_every=4, block=64,
                                 policy=pol)
    total = float(st.sketch_base.sum() + st.sketch_delta.sum())
    assert total == pol.depth * m


def test_multisource_policy_state_cold_start():
    """A policy-on call over a state that predates the policy (no sketch
    lanes) cold-starts the sketch instead of failing."""
    n, S = 32, 2
    keys = zipf_keys(8192)
    _, st0 = ref_porc_multisource(keys, n, S, block=64)   # no policy
    assert st0.sketch_base is None
    pol = HHPolicy(scheme="w")
    _, st1 = ref_porc_multisource(keys, n, S, block=64, state=st0,
                                  policy=pol)
    assert float(st1.sketch_base.sum() + st1.sketch_delta.sum()) \
        == pol.depth * 8192


def test_policy_rejects_strict_engine():
    with pytest.raises(ValueError):
        ref_porc_multisource(zipf_keys(1024), 16, 2, engine="strict",
                             policy=HHPolicy())


# ---------------------------------------------------------------------------
# partitioners registry
# ---------------------------------------------------------------------------

def test_route_registry_hh_schemes():
    keys = zipf_keys(8192)
    for scheme in P.HH_SCHEMES:
        a = P.route(scheme, keys, 32)
        assert a.shape == (8192,)
        assert int(np.bincount(np.asarray(a), minlength=32).sum()) == 8192
    # multi-source variant exists
    a = P.route("WCHOICES", keys, 32, sources=4, sync_every=2)
    assert a.shape == (8192,)


def test_route_registry_rejects_hh_elsewhere():
    keys = zipf_keys(256)
    with pytest.raises(ValueError):
        P.route("PORC", keys, 32, hh=HHPolicy())


def test_d_w_choices_override_policy():
    keys = zipf_keys(8192, z=1.8)
    # the hh override keeps its knobs but the scheme letter is forced
    a = P.d_choices(keys, 32, hh=HHPolicy(scheme="w", d_tail=3))
    assert a.shape == (8192,)


# ---------------------------------------------------------------------------
# CG runtime
# ---------------------------------------------------------------------------

def test_cg_hh_runs_and_carries_sketch():
    cfg = cg.CGConfig(n_workers=8, slot_len=4096, block_size=128,
                      hh_scheme="w")
    keys = zipf_keys(16384)
    caps = jnp.ones(8, jnp.float32) / 8
    res = cg.run(cfg, keys, caps)
    assert float(res.state.sketch.sum()) == cfg.sketch_depth * 16384
    # split == whole with the sketch riding along
    r1 = cg.run(cfg, keys[:8192], caps)
    r2 = cg.run(cfg, keys[8192:], caps, state=r1.state)
    np.testing.assert_array_equal(
        np.asarray(res.assignment),
        np.concatenate([np.asarray(r1.assignment), np.asarray(r2.assignment)]))


def test_cg_hh_off_state_has_no_sketch():
    cfg = cg.CGConfig(n_workers=4, slot_len=2048, block_size=128)
    res = cg.run(cfg, zipf_keys(4096), jnp.ones(4, jnp.float32) / 4)
    assert res.state.sketch is None


def test_cg_hh_requires_block_path():
    with pytest.raises(ValueError):
        cg.hh_policy(cg.CGConfig(n_workers=4, hh_scheme="d", block_size=0))
    with pytest.raises(ValueError):
        cg.hh_policy(cg.CGConfig(n_workers=4, hh_scheme="d", inner="KG"))


def test_hh_scheme_spellings_normalize_to_kernel_letter():
    # regression: "WCHOICES" must not silently degrade to D semantics
    # (the kernel ceiling switch compares scheme == "w")
    for spelled, letter in [("w", "w"), ("WCHOICES", "w"),
                            ("wchoices", "w"), ("d", "d"),
                            ("DCHOICES", "d")]:
        pol = cg.hh_policy(cg.CGConfig(n_workers=4, hh_scheme=spelled))
        assert pol.scheme == letter, (spelled, pol.scheme)
    with pytest.raises(ValueError):
        cg.hh_policy(cg.CGConfig(n_workers=4, hh_scheme="PORC"))
    from repro.serve.engine import CGRequestRouter
    rt = CGRequestRouter(n_replicas=4, hh_scheme="WCHOICES")
    assert rt._policy.scheme == "w"
    with pytest.raises(ValueError):
        CGRequestRouter(n_replicas=4, hh_scheme="x")


def test_cg_hh_cold_start_from_policy_off_state():
    cfg_off = cg.CGConfig(n_workers=4, slot_len=2048, block_size=128)
    caps = jnp.ones(4, jnp.float32) / 4
    r0 = cg.run(cfg_off, zipf_keys(4096), caps)
    cfg_on = cfg_off._replace(hh_scheme="w")
    r1 = cg.run(cfg_on, zipf_keys(4096, seed=1), caps, state=r0.state)
    assert float(r1.state.sketch.sum()) == cfg_on.sketch_depth * 4096


# ---------------------------------------------------------------------------
# serving router
# ---------------------------------------------------------------------------

def test_serve_router_hh_conservation_and_single_route():
    from repro.serve.engine import CGRequestRouter
    keys = np.asarray(zipf_keys(9000), np.int32)
    rt = CGRequestRouter(n_replicas=8, hh_scheme="w")
    assign = rt.route_batch(keys)
    assert assign.shape == (9000,)
    assert (0 <= assign).all() and (assign < 8).all()
    assert float(rt.vw_load.sum()) == 9000.0
    # single-request path delegates to the batch engine under a policy
    r = rt.route(int(keys[0]))
    assert 0 <= r < 8
    assert rt.routed == 9001
    assert float(rt._state.sketch_base.sum()
                 + rt._state.sketch_delta.sum()) == rt.sketch_depth * 9001


def test_serve_router_hh_off_is_policy_free():
    from repro.serve.engine import CGRequestRouter
    keys = np.asarray(zipf_keys(4096), np.int32)
    rt_off = CGRequestRouter(n_replicas=4)
    rt_on = CGRequestRouter(n_replicas=4, hh_scheme="")
    np.testing.assert_array_equal(rt_off.route_batch(keys),
                                  rt_on.route_batch(keys))
    assert rt_on._policy is None and rt_on._state.sketch_base is None


def test_serve_router_vw_load_restore_rescales_sketch():
    from repro.serve.engine import CGRequestRouter
    keys = np.asarray(zipf_keys(8192), np.int32)
    rt = CGRequestRouter(n_replicas=4, hh_scheme="w")
    rt.route_batch(keys)
    restored = rt.vw_load / 2.0
    rt.vw_load = restored
    assert rt.routed == int(restored.sum())
    mass = float(rt._state.sketch_base.sum()) / rt.sketch_depth
    assert abs(mass - rt.routed) <= 1.0
