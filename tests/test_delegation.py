"""The shared delegation engine (repro.core.delegation): seed-pairing
parity, capacity-weighted budgets, FCFS carry-over, windowed rates."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delegation as D


def _state(owner, n, rate=None):
    owner = np.asarray(owner, np.int32)
    V = owner.shape[0]
    return D.DelegationState(
        vw_owner=jnp.asarray(owner),
        vw_rate=(jnp.zeros(V, jnp.float32) if rate is None
                 else jnp.asarray(rate, jnp.float32)),
        queues=D.init_queues(n),
        moves=jnp.zeros((), jnp.int32))


def _step(cfg, st, util, load, caps=None):
    n = cfg.n_workers
    return D.rebalance_step(
        cfg, st, jnp.asarray(util, jnp.float32),
        jnp.asarray(util > 0.85), jnp.asarray(util < 0.75),
        jnp.asarray(load, jnp.float32),
        jnp.ones(n, jnp.float32) if caps is None
        else jnp.asarray(caps, jnp.float32))


# the seed-pairing specification lives next to the engine so the test
# suite and the benchmark parity gate assert against one oracle
_seed_paired_moves = D.seed_pairing_reference


def test_uniform_parity_with_seed_pairing():
    """The uniform-capacity engine must reproduce the seed's
    one-VW-per-pair severity pairing bit-for-bit whenever every busy
    worker owns at least one VW (the seed's well-defined regime)."""
    rng = np.random.default_rng(7)
    for _ in range(100):
        n = int(rng.integers(2, 12))
        a = int(rng.integers(1, 6))
        V, M = n * a, int(rng.integers(1, 10))
        owner = np.repeat(np.arange(n), a).astype(np.int32)
        rng.shuffle(owner)
        owner[:n] = np.arange(n)
        load = (rng.random(V) * 100).astype(np.float32)
        util = (rng.random(n) * 1.6).astype(np.float32)
        exp_owner, exp_done = _seed_paired_moves(n, M, load, owner, util)
        cfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                                 max_moves_per_slot=M)
        st, moved = _step(cfg, _state(owner, n), util, load)
        np.testing.assert_array_equal(np.asarray(st.vw_owner), exp_owner)
        assert int(moved) == exp_done


def test_busy_worker_with_no_vws_skipped():
    """A busy worker owning no VWs must not burn a pairing slot: the
    budget skips to the next eligible busy worker (the seed burned the
    pair and moved nothing)."""
    n, V = 4, 8
    # worker 0: most severe, owns nothing; worker 1: busy, owns all
    owner = np.full(V, 1, np.int32)
    util = np.array([1.5, 1.2, 0.5, 0.8], np.float32)
    load = np.arange(V, dtype=np.float32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=1)
    seed_owner, seed_done = _seed_paired_moves(n, 1, load, owner, util)
    assert seed_done == 0                      # the seed burns the slot
    st, moved = _step(cfg, _state(owner, n), util, load)
    assert int(moved) == 1                     # the engine does real work
    got = np.asarray(st.vw_owner)
    # worker 1's hottest VW (id 7) moved to the most idle worker (2)
    assert got[7] == 2
    assert (got[:7] == 1).all()


def test_counts_only_executed_moves():
    n, V = 3, 3
    owner = np.array([0, 1, 2], np.int32)
    util = np.array([1.5, 0.5, 0.8], np.float32)   # one pair possible
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=8)
    st, moved = _step(cfg, _state(owner, n), util, np.ones(V))
    assert int(moved) == 1 == int(st.moves)


def test_capacity_weighted_sheds_to_share():
    """A slow worker sheds VWs until its load matches its capacity
    share — several per slot, not one per signal — and the VW
    population is conserved."""
    n, a = 4, 8
    V = n * a
    owner = np.repeat(np.arange(n), a).astype(np.int32)   # 8 VWs each
    load = np.ones(V, np.float32)                          # uniform rates
    caps = np.array([0.3, 1.0, 1.0, 1.0], np.float32)
    # worker 0 is 0.3x: its fair share is 32*0.3/3.3 ≈ 2.9 VWs, so it
    # should shed ~5 VWs; workers 1-3 idle, worker 0 busy.
    util = np.array([2.0, 0.5, 0.5, 0.5], np.float32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=8,
                             capacity_weighted=True, rate_decay=0.6)
    st, moved = _step(cfg, _state(owner, n), util, load, caps)
    got = np.asarray(st.vw_owner)
    counts = np.bincount(got, minlength=n)
    assert counts.sum() == V                    # population conserved
    assert int(moved) == 5
    assert counts[0] == 3                       # ≈ capacity share of 2.9
    # uniform budgets would have moved exactly one
    cfg_u = cfg._replace(capacity_weighted=False)
    _, moved_u = _step(cfg_u, _state(owner, n), util, load, caps)
    assert int(moved_u) == 1


def test_capacity_weighted_respects_global_budget():
    n, a = 4, 8
    V = n * a
    owner = np.repeat(np.arange(n), a).astype(np.int32)
    caps = np.array([0.1, 1.0, 1.0, 1.0], np.float32)
    util = np.array([3.0, 0.5, 0.5, 0.5], np.float32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=3,
                             capacity_weighted=True)
    st, moved = _step(cfg, _state(owner, n), util, np.ones(V), caps)
    assert int(moved) == 3                      # clipped at the budget
    assert np.bincount(np.asarray(st.vw_owner), minlength=n).sum() == V


def test_fcfs_carryover_across_slots():
    """A busy signal the budget could not serve keeps its place at the
    head of the queue: next slot it is served before a newer, even more
    severe, signal (the paper's FCFS queues)."""
    n, V = 4, 8
    owner = np.array([0, 0, 1, 1, 2, 2, 3, 3], np.int32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=1,
                             fcfs=True)
    # slot 0: workers 0 (severe) and 1 (less) busy, worker 3 idle
    util0 = np.array([1.8, 1.2, 0.80, 0.5], np.float32)
    st = _state(owner, n)
    st, moved = _step(cfg, st, util0, np.ones(V))
    assert int(moved) == 1
    assert np.asarray(st.vw_owner)[0] == 3      # worker 0 shed first
    assert int(st.queues.busy_since[1]) != int(D.NOT_QUEUED)  # 1 carried
    # slot 1: worker 2 turns busy *more severe* than 1; FCFS serves 1
    util1 = np.array([0.8, 1.2, 1.9, 0.5], np.float32)
    st, moved = _step(cfg, st, util1, np.zeros(V))
    assert int(moved) == 1
    got = np.asarray(st.vw_owner)
    assert (got == np.array([3, 0, 3, 1, 2, 2, 3, 3])).all()
    # worker 2 is still queued for the next slot
    assert int(st.queues.busy_since[2]) != int(D.NOT_QUEUED)


def test_fcfs_opposite_signal_dequeues():
    n, V = 3, 6
    owner = np.repeat(np.arange(n), 2).astype(np.int32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=1,
                             fcfs=True)
    st = _state(owner, n)
    # two busy, no idle: nothing can move, both carried
    st, moved = _step(cfg, st, np.array([1.5, 1.2, 0.8], np.float32),
                      np.ones(V))
    assert int(moved) == 0
    assert int(st.queues.busy_since[0]) != int(D.NOT_QUEUED)
    # worker 0 flips to idle: it must leave the busy queue and absorb
    st, moved = _step(cfg, st, np.array([0.5, 1.5, 0.8], np.float32),
                      np.zeros(V))
    assert int(moved) == 1
    assert int(st.queues.busy_since[0]) == int(D.NOT_QUEUED)
    assert np.bincount(np.asarray(st.vw_owner), minlength=n)[0] == 3


def test_ewma_rate_tracks_recent_traffic():
    """With rate_decay < 1 the migrated VW is the *recently* hottest
    one, not the cumulatively hottest (the seed behaviour)."""
    n, V = 2, 4
    owner = np.array([0, 0, 1, 1], np.int32)
    util = np.array([1.5, 0.5], np.float32)
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=1,
                             rate_decay=0.5)
    st = _state(owner, n)
    # slot 0: VW 0 historically hot, no move possible yet (both busy? no:
    # worker 1 idle) — use a no-signal slot to load history instead
    st, _ = _step(cfg, st, np.array([0.8, 0.8], np.float32),
                  np.array([100.0, 0.0, 0.0, 0.0], np.float32))
    # slots 1-4: VW 1 is the hot one now; rates decay 100 → 6.25
    for _ in range(4):
        st, _ = _step(cfg, st, np.array([0.8, 0.8], np.float32),
                      np.array([0.0, 15.0, 0.0, 0.0], np.float32))
    st, moved = _step(cfg, st, util,
                      np.array([0.0, 15.0, 0.0, 0.0], np.float32))
    assert int(moved) == 1
    assert np.asarray(st.vw_owner)[1] == 1      # recent-hot VW moved
    assert np.asarray(st.vw_owner)[0] == 0      # cumulative-hot stayed
    # cumulative mode (the seed) would have moved VW 0 instead
    cfg_c = cfg._replace(rate_decay=1.0)
    st_c = _state(owner, n)
    st_c, _ = _step(cfg_c, st_c, np.array([0.8, 0.8], np.float32),
                    np.array([100.0, 0.0, 0.0, 0.0], np.float32))
    for _ in range(4):
        st_c, _ = _step(cfg_c, st_c, np.array([0.8, 0.8], np.float32),
                        np.array([0.0, 15.0, 0.0, 0.0], np.float32))
    st_c, _ = _step(cfg_c, st_c, util,
                    np.array([0.0, 15.0, 0.0, 0.0], np.float32))
    assert np.asarray(st_c.vw_owner)[0] == 1


def test_plan_pairs_severity_and_carryover():
    """plan_pairs (the owner-less entry point) pairs in severity order
    with unit budgets and carries the unserved signal over."""
    n = 4
    cfg = D.DelegationConfig(n_workers=n, n_virtual=0,
                             max_moves_per_slot=1, fcfs=True)
    q = D.init_queues(n)
    pressure = jnp.asarray([2.0, 3.0, 0.5, 1.0])
    busy = jnp.asarray([True, True, False, False])
    idle = jnp.asarray([False, False, True, False])
    src, dst, k, q = D.plan_pairs(cfg, q, pressure, busy, idle)
    assert int(k) == 1
    assert int(src[0]) == 1 and int(dst[0]) == 2   # most severe ↔ most idle
    # next slot: same signals — the carried worker 0 is served first
    src, dst, k, q = D.plan_pairs(cfg, q, pressure, busy, idle)
    assert int(k) == 1
    assert int(src[0]) == 0 and int(dst[0]) == 2


def test_plan_pairs_byte_clamp():
    """With a per-slot byte budget and a caller-supplied unit_bytes, the
    pair count is clamped to the bytes the slot may migrate."""
    n = 6
    cfg = D.DelegationConfig(n_workers=n, n_virtual=0,
                             max_moves_per_slot=4,
                             byte_budget_per_slot=250.0)
    pressure = jnp.asarray([3.0, 2.5, 2.0, 0.1, 0.2, 0.3])
    busy = jnp.asarray([True, True, True, False, False, False])
    idle = ~busy
    # 100 bytes per move → floor(250/100) = 2 of the 3 eligible pairs
    _, _, k, _ = D.plan_pairs(cfg, D.init_queues(n), pressure, busy, idle,
                              unit_bytes=100.0)
    assert int(k) == 2
    # no unit_bytes → byte budget inert, all 3 pairs scheduled
    _, _, k, _ = D.plan_pairs(cfg, D.init_queues(n), pressure, busy, idle)
    assert int(k) == 3
    # budget off → unit_bytes inert too
    cfg0 = cfg._replace(byte_budget_per_slot=0.0)
    _, _, k, _ = D.plan_pairs(cfg0, D.init_queues(n), pressure, busy, idle,
                              unit_bytes=100.0)
    assert int(k) == 3


def test_plan_pairs_byte_clamp_floors_at_one():
    """A unit_bytes larger than the whole slot budget rate-limits to one
    pair per slot — the same floor controller_step applies — instead of
    clamping to zero and wedging callers that need forward progress."""
    n = 6
    cfg = D.DelegationConfig(n_workers=n, n_virtual=0,
                             max_moves_per_slot=4,
                             byte_budget_per_slot=250.0)
    pressure = jnp.asarray([3.0, 2.5, 2.0, 0.1, 0.2, 0.3])
    busy = jnp.asarray([True, True, True, False, False, False])
    idle = ~busy
    _, _, k, _ = D.plan_pairs(cfg, D.init_queues(n), pressure, busy, idle,
                              unit_bytes=1000.0)
    assert int(k) == 1


@pytest.mark.parametrize("capacity_weighted", [False, True])
def test_random_streams_conserve_population(capacity_weighted):
    rng = np.random.default_rng(3)
    n, a = 6, 5
    V = n * a
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V, max_moves_per_slot=6,
                             capacity_weighted=capacity_weighted,
                             rate_decay=0.7, fcfs=True)
    st = _state(np.repeat(np.arange(n), a), n)
    caps = rng.random(n).astype(np.float32) + 0.2
    for _ in range(30):
        util = (rng.random(n) * 1.6).astype(np.float32)
        load = (rng.random(V) * 10).astype(np.float32)
        st, _ = _step(cfg, st, util, load, caps)
        got = np.asarray(st.vw_owner)
        assert got.shape == (V,)
        assert got.min() >= 0 and got.max() < n
        assert np.bincount(got, minlength=n).sum() == V
