"""Mesh-sharded serving: shard_map kernel parity with the vmapped
engine, versioned owner-map semantics, and the async submit path's
conservation invariant under chaos.

The mesh tests parametrize over every host count that divides the
available device pool — on the default single-device tier-1 run that is
H=1 (which still exercises the full shard_map + psum program); the CI
multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
runs the real 2/4/8-host cells.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delegation as D
from repro.kernels.mesh import mesh_porc_multisource, shard_multisource_state
from repro.kernels.ref import (HHPolicy, multisource_state_init,
                               ref_porc_multisource)
from repro.launch.mesh import make_source_mesh
from repro.runtime.chaos import ChaosSchedule
from repro.serve import CGRequestRouter, MeshCGRequestRouter, ServingEngine

HOSTS = [h for h in (1, 2, 8) if h <= len(jax.devices())]


def _zipf_keys(n, seed=0, a=1.3, mod=4096):
    rng = np.random.default_rng(seed)
    return (rng.zipf(a, n) % mod).astype(np.int32)


# -- shard_map kernel vs vmapped engine -------------------------------------

@pytest.mark.parametrize("hosts", HOSTS)
@pytest.mark.parametrize("sync_every", [1, 3])
def test_mesh_kernel_bit_identical_to_vmapped(hosts, sync_every):
    """The psum delta-merge on the mesh is the same arithmetic as the
    vmapped ``delta.sum(0)`` — assignments and every state field are
    bit-identical, including power-of-two remainder spans and the
    sub-S ragged tail (stream length chosen to hit both)."""
    keys = jnp.asarray(_zipf_keys(4103))
    mesh = make_source_mesh(hosts)
    a_ref, s_ref = ref_porc_multisource(keys, 64, 8, sync_every=sync_every)
    a_mesh, s_mesh = mesh_porc_multisource(keys, 64, mesh, n_sources=8,
                                           sync_every=sync_every)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_mesh))
    np.testing.assert_array_equal(np.asarray(s_ref.base),
                                  np.asarray(s_mesh.base))
    np.testing.assert_array_equal(np.asarray(s_ref.delta),
                                  np.asarray(s_mesh.delta))
    assert int(s_ref.ticks) == int(s_mesh.ticks)
    assert float(s_ref.routed) == float(s_mesh.routed)


@pytest.mark.parametrize("hosts", HOSTS)
def test_mesh_kernel_state_carries_across_calls(hosts):
    """Mesh calls thread their sharded lane state exactly like the
    vmapped engine threads its vmapped state: the same two-call split
    (cut mid-block AND mid-source-round, so the ragged tail and the
    post-tail delta re-pin are both exercised) stays bit-identical."""
    keys = _zipf_keys(3000, seed=3)
    mesh = make_source_mesh(hosts)
    cut = 1499                        # not a multiple of S=8: tail path
    a1, sr = ref_porc_multisource(jnp.asarray(keys[:cut]), 64, 8,
                                  sync_every=2)
    a2, sr = ref_porc_multisource(jnp.asarray(keys[cut:]), 64, 8,
                                  sync_every=2, state=sr)
    b1, sm = mesh_porc_multisource(jnp.asarray(keys[:cut]), 64, mesh,
                                   n_sources=8, sync_every=2)
    b2, sm = mesh_porc_multisource(jnp.asarray(keys[cut:]), 64, mesh,
                                   n_sources=8, sync_every=2, state=sm)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(a1), np.asarray(a2)]),
        np.concatenate([np.asarray(b1), np.asarray(b2)]))
    np.testing.assert_array_equal(np.asarray(sr.base),
                                  np.asarray(sm.base))
    np.testing.assert_array_equal(np.asarray(sr.delta),
                                  np.asarray(sm.delta))


def test_mesh_lane_sharding_layout():
    """Each host really owns its delta lane rows: the sharded state's
    delta is split over the ``sources`` axis, base is replicated."""
    mesh = make_source_mesh(len(jax.devices()))
    st = shard_multisource_state(multisource_state_init(32, 8), mesh)
    H = mesh.shape["sources"]
    shard_rows = {s.data.shape[0] for s in st.delta.addressable_shards}
    assert shard_rows == {8 // H}
    assert all(s.data.shape == (32,) for s in st.base.addressable_shards)


def test_shard_state_rejects_policy_and_indivisible():
    mesh = make_source_mesh(1)
    with pytest.raises(NotImplementedError):
        shard_multisource_state(
            multisource_state_init(32, 4, policy=HHPolicy(scheme="d")), mesh)
    if len(jax.devices()) > 1:
        mesh = make_source_mesh(2)
        with pytest.raises(ValueError):
            shard_multisource_state(multisource_state_init(32, 3), mesh)


# -- mesh router vs single-host router --------------------------------------

@pytest.mark.parametrize("hosts", HOSTS)
def test_mesh_router_parity_with_single_host(hosts):
    """MeshCGRequestRouter routes and rebalances bit-identically to
    CGRequestRouter at matching config (sync_every=1 — the CI-gated
    exactness cell) across interleaved batches and rebalances."""
    kw = dict(n_replicas=4, alpha=4, n_sources=8, sync_every=1,
              capacity_weighted=True)
    r0 = CGRequestRouter(**kw)
    r1 = MeshCGRequestRouter(mesh=make_source_mesh(hosts), **kw)
    keys = _zipf_keys(5400, seed=1)
    rng = np.random.default_rng(2)
    for i in range(6):
        np.testing.assert_array_equal(
            r0.route_batch(keys[i * 900:(i + 1) * 900]),
            r1.route_batch(keys[i * 900:(i + 1) * 900]))
        occ = rng.random(4).astype(np.float32)
        busy, idle = [int(np.argmax(occ))], [int(np.argmin(occ))]
        assert r0.rebalance(busy, idle, pressure=occ) == \
            r1.rebalance(busy, idle, pressure=occ)
        np.testing.assert_array_equal(r0.vw_owner, r1.vw_owner)
    np.testing.assert_allclose(r0.vw_load, r1.vw_load)


def test_mesh_router_rejects_hh_and_indivisible_sources():
    with pytest.raises(NotImplementedError):
        MeshCGRequestRouter(n_replicas=4, hh_scheme="d",
                            mesh=make_source_mesh(1))
    if len(jax.devices()) > 1:
        with pytest.raises(ValueError):
            MeshCGRequestRouter(n_replicas=4, n_sources=3,
                                mesh=make_source_mesh(2))


# -- versioned owner map ----------------------------------------------------

def test_versioned_owner_map_commit_adopt_view():
    omap = D.VersionedOwnerMap(jnp.arange(4, dtype=jnp.int32))
    assert omap.version == 0 and omap.base_version == 0
    v1 = omap.commit(jnp.array([1, 1, 2, 3], jnp.int32))
    assert v1 == 1 and omap.base_version == 0
    # a stale router (version 0) sees the base; a current one the head
    np.testing.assert_array_equal(np.asarray(omap.view(0)),
                                  np.arange(4))
    np.testing.assert_array_equal(np.asarray(omap.view(1)), [1, 1, 2, 3])
    assert omap.adopt() == 1
    np.testing.assert_array_equal(np.asarray(omap.view(0)), [1, 1, 2, 3])


def test_owner_version_monotone_under_rebalance_and_evacuate():
    """Interleaved rebalances and an evacuation commit strictly
    increasing versions; the evacuation (a forced update) adopts
    immediately."""
    r = MeshCGRequestRouter(n_replicas=4, alpha=4, n_sources=8,
                            mesh=make_source_mesh(HOSTS[-1]))
    r.route_batch(_zipf_keys(1024))
    seen = [r.owner_version]
    occ = np.array([0.9, 0.2, 0.5, 0.5], np.float32)
    for i in range(3):
        if r.rebalance([0], [1], pressure=occ):
            assert r.owner_version > seen[-1]
            seen.append(r.owner_version)
    n_moved, _ = r.evacuate(0)
    assert n_moved > 0
    assert r.owner_version > seen[-1]
    assert r.owner_adopted_version == r.owner_version  # forced adopt
    assert not (r.vw_owner == 0).any()
    assert seen == sorted(seen)


def test_stale_owner_fallback_routes_on_base_view():
    """owner_sync_every=3: rebalance commits land in the head but the
    submit path keeps gathering from the pre-move base snapshot until
    enough commits accumulate — stale routers are conservative, never
    torn."""
    r = MeshCGRequestRouter(n_replicas=4, alpha=4, n_sources=8,
                            owner_sync_every=3,
                            mesh=make_source_mesh(HOSTS[-1]))
    r.route_batch(_zipf_keys(1024))
    before = r.vw_owner
    occ = np.array([0.9, 0.2, 0.5, 0.5], np.float32)
    assert r.rebalance([0], [1], pressure=occ) == 1
    after = r.vw_owner
    assert (before != after).any()
    assert r.owner_version > r.owner_adopted_version
    # the routing view is still the pre-move snapshot, as one piece
    np.testing.assert_array_equal(np.asarray(r._owner_view()), before)
    # two more commits reach the adoption period: the head is adopted
    assert r.rebalance([0], [1], pressure=occ) == 1
    assert r.rebalance([0], [1], pressure=occ) == 1
    assert r.owner_adopted_version == r.owner_version
    np.testing.assert_array_equal(np.asarray(r._owner_view()), r.vw_owner)


# -- async submit -----------------------------------------------------------

def _mesh_engine(n=4, hosts=None, **kw):
    router = MeshCGRequestRouter(
        n_replicas=n, alpha=4, n_sources=8, capacity_weighted=True,
        mesh=make_source_mesh(hosts or HOSTS[-1]))
    return ServingEngine([lambda b: b for _ in range(n)], router,
                         max_batch=8, **kw)


def test_async_submit_conservation_under_chaos():
    """submitted == served + in_flight at every tick with async
    admission pending, a kill-one on the mesh, and retries in flight;
    the drain ends with zero in flight and zero dropped."""
    eng = _mesh_engine(4, chaos=ChaosSchedule.kill_one(2, at=6),
                       heartbeat_timeout_steps=2, async_submit=True)
    rng = np.random.default_rng(5)
    for _ in range(30):
        keys = rng.zipf(1.3, size=32).astype(np.int32) % 512
        eng.submit_batch(keys, list(keys))
        served = sum(r.served for r in eng.replicas)
        assert eng.submitted == served + eng.in_flight   # pending counts
        eng.step()
        served = sum(r.served for r in eng.replicas)
        assert eng.submitted == served + eng.in_flight
    for _ in range(300):
        if eng.in_flight == 0:
            break
        eng.step()
    assert eng.in_flight == 0 and eng.dropped == 0
    assert eng.evacuations == 1
    served = sum(r.served for r in eng.replicas)
    assert eng.submitted == served


def test_async_submit_admits_next_tick_and_serves_everything():
    """The async path delays admission by one tick (routing overlaps
    the drain) but serves the same totals as the sync path."""
    results = {}
    for async_ in (False, True):
        eng = _mesh_engine(4, async_submit=async_)
        for i in range(10):
            eng.submit_batch(_zipf_keys(64, seed=i), [None] * 64)
            eng.step()
        for _ in range(100):
            if eng.in_flight == 0:
                break
            eng.step()
        assert eng.in_flight == 0 and eng.dropped == 0
        results[async_] = sum(r.served for r in eng.replicas)
    assert results[False] == results[True] == 640


def test_async_admission_to_declared_dead_replica_retries():
    """A dispatch admitted through a view that still maps VWs to a
    declared-dead replica must not enqueue onto the corpse — it goes to
    the retry queue (conservation holds either way)."""
    eng = _mesh_engine(4, async_submit=True)
    before = eng.router.vw_owner
    eng.submit_batch(np.arange(64, dtype=np.int32), [None] * 64)
    eng.fail_replica(0)               # declared + evacuated immediately
    eng.router.vw_owner = before      # a stale router's map resurfaces
    eng.step()                        # admission happens after liveness
    assert len(eng.replicas[0].queue) == 0   # nothing on the corpse
    assert eng.retried > 0
    served = sum(r.served for r in eng.replicas)
    assert eng.submitted == served + eng.in_flight
