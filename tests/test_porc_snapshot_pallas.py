"""Pallas snapshot-probing block engine vs the jnp oracles (interpret).

The kernel (``kernels.porc_snapshot``) must be *bit-identical* to the
jnp fast path — same assignments, same float load vectors, same sketch
counters — because its block bodies call the very same ``kernels.blocks``
math the ref engine uses. Everything here runs the kernel in interpret
mode (the CI backend is CPU), which executes the kernel body with real
JAX ops: parity here is the semantics gate for the compiled TPU path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partitioners as P
from repro.core import streams
from repro.kernels.backend import resolve_engine, resolve_interpret
from repro.kernels.blocks import HHPolicy, neutral_hh_policy
from repro.kernels.porc_snapshot import porc_multisource_scan, porc_snapshot
from repro.kernels.ref import (multisource_state_init, porc_state_init,
                               ref_porc_multisource, ref_porc_route,
                               ref_porc_snapshot)


def zipf_keys(m, z=1.3, n_keys=1000, seed=1):
    return streams.sample_zipf_stream(jax.random.PRNGKey(seed), m, n_keys, z)


# ---------------------------------------------------------------------------
# single source
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_bins", [8, 100, 256])
@pytest.mark.parametrize("block", [64, 128])
def test_kernel_matches_snapshot_ref(n_bins, block):
    keys = zipf_keys(4096)
    a_ref, l_ref = ref_porc_snapshot(keys, n_bins, block=block, eps=0.05)
    a_k, l_k = porc_snapshot(keys, n_bins, block=block, eps=0.05,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    # float loads must match *bit-exactly*: the kernel shares the ref's
    # cap expression and accumulation order (blocks.snapshot_cap)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_k))


def test_kernel_b1_equals_sequential_oracle():
    """block=1 runs the full lazy probe chain — exact Alg. 1."""
    keys = zipf_keys(512)
    oracle = P.power_of_random_choices(keys, 32, eps=0.05)
    a, _ = porc_snapshot(keys, 32, block=1, eps=0.05, interpret=True)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(a))


def test_kernel_continuation_equals_one_shot():
    """(m0, load0) carry across calls exactly like the ref."""
    n = 32
    keys = zipf_keys(2048, n_keys=500, z=1.2, seed=3)
    a_full, l_full = porc_snapshot(keys, n, eps=0.05, interpret=True)
    a1, l1 = porc_snapshot(keys[:1024], n, eps=0.05, interpret=True)
    a2, l2 = porc_snapshot(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_array_equal(np.asarray(l_full), np.asarray(l2))


def test_route_engine_pallas_ragged_stream():
    """ref_porc_route(engine='pallas') on a ragged length: full blocks
    through the kernel, power-of-two remainder spans, same state."""
    keys = zipf_keys(4096 + 37)
    a_ref, s_ref = ref_porc_route(keys, 64, block=128, eps=0.05)
    a_k, s_k = ref_porc_route(keys, 64, block=128, eps=0.05,
                              engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(s_ref.load),
                                  np.asarray(s_k.load))
    assert float(s_ref.routed) == float(s_k.routed)


def test_route_state_carry_across_calls():
    keys = zipf_keys(2048)
    a_full, _ = ref_porc_route(keys, 32, block=64, engine="pallas")
    state = porc_state_init(32)
    a1, state = ref_porc_route(keys[:1024], 32, block=64, state=state,
                               engine="pallas")
    a2, state = ref_porc_route(keys[1024:], 32, block=64, state=state,
                               engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))


# ---------------------------------------------------------------------------
# heavy-hitter policy path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [
    HHPolicy(scheme="w", width=256),
    HHPolicy(scheme="d", width=256, d_heavy=16, d_tail=2),
], ids=["wchoices", "dchoices"])
def test_hh_policy_parity(policy):
    keys = zipf_keys(4096, z=1.4)
    a_ref, s_ref = ref_porc_route(keys, 64, block=128, policy=policy)
    a_k, s_k = ref_porc_route(keys, 64, block=128, policy=policy,
                              engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(s_ref.load),
                                  np.asarray(s_k.load))
    np.testing.assert_array_equal(np.asarray(s_ref.sketch),
                                  np.asarray(s_k.sketch))


def test_neutral_policy_matches_policy_free_kernel():
    """The neutral policy reproduces the plain engine through the HH
    code path — on the Pallas kernel too."""
    keys = zipf_keys(2048)
    a_plain, _ = ref_porc_route(keys, 32, block=128, engine="pallas")
    a_neut, _ = ref_porc_route(keys, 32, block=128, engine="pallas",
                               policy=neutral_hh_policy(32))
    np.testing.assert_array_equal(np.asarray(a_plain), np.asarray(a_neut))


# ---------------------------------------------------------------------------
# multisource
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_sources", [1, 4])
@pytest.mark.parametrize("sync_every", [1, 3])
def test_multisource_parity(n_sources, sync_every):
    keys = zipf_keys(4096 + 21)
    a_ref, s_ref = ref_porc_multisource(keys, 64, n_sources,
                                        sync_every=sync_every, block=64)
    a_k, s_k = ref_porc_multisource(keys, 64, n_sources,
                                    sync_every=sync_every, block=64,
                                    engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(s_ref.base),
                                  np.asarray(s_k.base))
    np.testing.assert_array_equal(np.asarray(s_ref.delta),
                                  np.asarray(s_k.delta))
    assert int(s_ref.ticks) == int(s_k.ticks)


def test_multisource_hh_sketch_lanes_parity():
    policy = HHPolicy(scheme="w", width=256)
    keys = zipf_keys(4096, z=1.4)
    a_ref, s_ref = ref_porc_multisource(keys, 64, 4, sync_every=2,
                                        block=64, policy=policy)
    a_k, s_k = ref_porc_multisource(keys, 64, 4, sync_every=2, block=64,
                                    policy=policy, engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(s_ref.sketch_base),
                                  np.asarray(s_k.sketch_base))
    np.testing.assert_array_equal(np.asarray(s_ref.sketch_delta),
                                  np.asarray(s_k.sketch_delta))


def test_multisource_state_carry_across_calls():
    keys = zipf_keys(3072)
    a_full, _ = ref_porc_multisource(keys, 32, 2, sync_every=3, block=64,
                                     engine="pallas")
    state = multisource_state_init(32, 2)
    a1, state = ref_porc_multisource(keys[:1536], 32, 2, sync_every=3,
                                     block=64, state=state, engine="pallas")
    a2, state = ref_porc_multisource(keys[1536:], 32, 2, sync_every=3,
                                     block=64, state=state, engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))


def test_multisource_scan_kernel_direct():
    """The raw Pallas scan (full blocks only) against the ref state."""
    S, block, n_bins = 4, 64, 32
    keys = zipf_keys(S * block * 6)
    a_ref, s_ref = ref_porc_multisource(keys, n_bins, S, sync_every=2,
                                        block=block)
    base = jnp.zeros(n_bins, jnp.float32)
    delta = jnp.zeros((S, n_bins), jnp.float32)
    a_k, base_k, delta_k, ticks_k, _, _ = porc_multisource_scan(
        keys, n_bins, S, 2, block, 0.05, 8, base, delta, 0,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(s_ref.base),
                                  np.asarray(base_k))
    np.testing.assert_array_equal(np.asarray(s_ref.delta),
                                  np.asarray(delta_k))


# ---------------------------------------------------------------------------
# engine selection plumbing
# ---------------------------------------------------------------------------

def test_resolve_engine_mapping():
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_engine("ref") == "snapshot"
    assert resolve_engine("jnp") == "snapshot"
    assert resolve_engine("snapshot") == "snapshot"
    assert resolve_engine("strict") == "strict"
    assert resolve_engine("pallas") == "pallas"
    assert resolve_engine("auto") == ("pallas" if on_tpu else "snapshot")
    with pytest.raises(ValueError):
        resolve_engine("mosaic")


def test_resolve_interpret_default():
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_route_engine_validation():
    keys = zipf_keys(256)
    with pytest.raises(ValueError, match="no kernel engine"):
        P.route("KG", keys, 16, engine="pallas")
    with pytest.raises(ValueError, match="block path"):
        P.route("PORC", keys, 16, engine="pallas")   # sequential oracle
    # the block path accepts it and matches the ref engine
    a_ref = P.route("PORC", keys, 16, block_size=64, engine="ref")
    a_k = P.route("PORC", keys, 16, block_size=64, engine="pallas")
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
