"""CG MoE router behaviour inside the layer (paper technique site a)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.moe.layer import init_moe_params, moe_ffn
from repro.moe.router import route


def _cfg(router="cg"):
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    return cfg.replace(moe=__import__('dataclasses').replace(cfg.moe, router=router))


def test_layer_forward_and_metrics():
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    y, m = moe_ffn(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert 0.0 <= float(m["drop_frac"]) < 1.0
    assert float(m["max_load_frac"]) <= 1.0 + 1e-6


def test_cg_drops_fewer_than_topk():
    """The paper's headline effect at the MoE site: overflow probing
    strictly reduces dropped token-slots under a skewed router."""
    key = jax.random.PRNGKey(2)
    cfg_cg, cfg_tk = _cfg("cg"), _cfg("topk")
    # shared params; bias router logits to favor 2 experts hard
    p = init_moe_params(key, cfg_cg, jnp.bfloat16)
    p["router"] = p["router"] + 4.0 * jax.nn.one_hot(0, cfg_cg.moe.n_experts)
    x = jax.random.normal(key, (2, 64, cfg_cg.d_model), jnp.bfloat16)
    _, m_cg = moe_ffn(x, p, cfg_cg)
    _, m_tk = moe_ffn(x, p, cfg_tk)
    assert float(m_cg["drop_frac"]) < float(m_tk["drop_frac"])


def test_route_capacity_never_exceeded():
    cfg = _cfg()
    w = jax.random.normal(jax.random.PRNGKey(3),
                          (cfg.d_model, cfg.moe.n_experts), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (128, cfg.d_model))
    r = route(x, w, cfg.moe)
    cap = max(1, int(cfg.moe.capacity_factor * 128 * cfg.moe.top_k
                     / cfg.moe.n_experts))
    assert float(r.load.max()) <= cap


def test_grad_flows_through_layer():
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model),
                          jnp.bfloat16)

    def f(p):
        y, m = moe_ffn(x, p, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2) + m["aux_loss"]

    g = jax.grad(f)(p)
    gnorm = sum(float(jnp.abs(l.astype(jnp.float32)).sum())
                for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # expert weights get gradients (dispatch is differentiable)
    assert float(jnp.abs(g["w1"].astype(jnp.float32)).sum()) > 0
