"""CG MoE router behaviour inside the layer (paper technique site a)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.moe.layer import init_moe_params, moe_ffn
from repro.moe.router import (_aux_losses, expert_capacity_vector, route,
                              uniform_capacity)


def _cfg(router="cg", **moe_kw):
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    return cfg.replace(
        moe=dataclasses.replace(cfg.moe, router=router, **moe_kw))


def test_layer_forward_and_metrics():
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    y, m = moe_ffn(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert 0.0 <= float(m["drop_frac"]) < 1.0
    assert float(m["max_load_frac"]) <= 1.0 + 1e-6


def test_cg_drops_fewer_than_topk():
    """The paper's headline effect at the MoE site: overflow probing
    strictly reduces dropped token-slots under a skewed router."""
    key = jax.random.PRNGKey(2)
    cfg_cg, cfg_tk = _cfg("cg"), _cfg("topk")
    # shared params; bias router logits to favor 2 experts hard
    p = init_moe_params(key, cfg_cg, jnp.bfloat16)
    p["router"] = p["router"] + 4.0 * jax.nn.one_hot(0, cfg_cg.moe.n_experts)
    x = jax.random.normal(key, (2, 64, cfg_cg.d_model), jnp.bfloat16)
    _, m_cg = moe_ffn(x, p, cfg_cg)
    _, m_tk = moe_ffn(x, p, cfg_tk)
    assert float(m_cg["drop_frac"]) < float(m_tk["drop_frac"])


def test_route_capacity_never_exceeded():
    cfg = _cfg()
    w = jax.random.normal(jax.random.PRNGKey(3),
                          (cfg.d_model, cfg.moe.n_experts), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (128, cfg.d_model))
    r = route(x, w, cfg.moe)
    cap = max(1, int(cfg.moe.capacity_factor * 128 * cfg.moe.top_k
                     / cfg.moe.n_experts))
    assert float(r.load.max()) <= cap


def test_grad_flows_through_layer():
    cfg = _cfg()
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model),
                          jnp.bfloat16)

    def f(p):
        y, m = moe_ffn(x, p, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2) + m["aux_loss"]

    g = jax.grad(f)(p)
    gnorm = sum(float(jnp.abs(l.astype(jnp.float32)).sum())
                for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # expert weights get gradients (dispatch is differentiable)
    assert float(jnp.abs(g["w1"].astype(jnp.float32)).sum()) > 0


# ------------------- capacity formula: one source of truth (regression)

def test_uniform_capacity_matches_legacy_formula():
    """layer.py and router.py used to each inline max(1, int(cf*T*k/E));
    both now call uniform_capacity — pin it to the legacy arithmetic."""
    for cf in (0.37, 1.0, 1.25, 1.5, 2.71):
        for T, k, E in [(64, 2, 8), (128, 8, 128), (1, 1, 4), (96, 2, 16)]:
            assert uniform_capacity(cf, T, k, E) == \
                max(1, int(cf * T * k / E))


def test_layer_buffer_consistent_with_router_caps():
    """moe_ffn sizes its [B, E, C, D] buffers from the same
    expert_capacity_vector the router dispatches against."""
    cfg = _cfg(capacity_skew=3.0)
    T = 64
    caps = expert_capacity_vector(cfg.moe, T)
    assert len(caps) == cfg.moe.n_experts and max(caps) >= min(caps) >= 1
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, cfg.d_model),
                          jnp.bfloat16)
    y, m = moe_ffn(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    # load/cap_e <= 1 per expert under its OWN capacity, not C_max
    assert float(m["max_load_frac"]) <= 1.0 + 1e-6


@pytest.mark.parametrize("cf", [0.5, 1.0, 1.25, 2.0])
def test_max_load_frac_bounded_over_factor_sweep(cf):
    cfg = _cfg(capacity_factor=cf)
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    _, m = moe_ffn(x, p, cfg)
    assert float(m["max_load_frac"]) <= 1.0 + 1e-6


# ------------------------------------ expert_capacity_vector semantics

def test_capacity_skew_preserves_budget_and_ratio():
    cfg = _cfg(capacity_skew=3.0)
    T = 64
    E = cfg.moe.n_experts
    base = uniform_capacity(cfg.moe.capacity_factor, T, cfg.moe.top_k, E)
    caps = expert_capacity_vector(cfg.moe, T)
    assert abs(sum(caps) - E * base) <= E          # rounding slack
    assert caps == tuple(sorted(caps, reverse=True))
    assert caps[0] / caps[-1] == pytest.approx(1 + 3.0, rel=0.35)


def test_explicit_expert_capacities_win():
    E = _cfg().moe.n_experts
    explicit = tuple(range(2, 2 + E))
    cfg = _cfg(expert_capacities=explicit, capacity_skew=9.0)
    assert expert_capacity_vector(cfg.moe, 64) == explicit


def test_expert_capacities_validation():
    E = _cfg().moe.n_experts
    with pytest.raises(ValueError):
        expert_capacity_vector(
            _cfg(expert_capacities=(4,) * (E - 1)).moe, 64)
    with pytest.raises(ValueError):
        expert_capacity_vector(
            _cfg(expert_capacities=(0,) + (4,) * (E - 1)).moe, 64)
    with pytest.raises(ValueError):
        expert_capacity_vector(_cfg(capacity_skew=-1.0).moe, 64)


def test_route_uniform_scalar_equals_uniform_vector():
    """capacity_skew=0 routes through the scalar dispatch; an explicit
    uniform expert_capacities vector must give identical results."""
    cfg0 = _cfg()
    T = 128
    caps = expert_capacity_vector(cfg0.moe, T)
    assert len(set(caps)) == 1
    cfg_v = _cfg(expert_capacities=caps)
    w = jax.random.normal(jax.random.PRNGKey(3),
                          (cfg0.d_model, cfg0.moe.n_experts), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (T, cfg0.d_model))
    r0, rv = route(x, w, cfg0.moe), route(x, w, cfg_v.moe)
    for a, b in zip(r0, rv):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_route_skewed_load_within_per_expert_caps():
    cfg = _cfg(capacity_skew=4.0)
    T = 128
    caps = np.asarray(expert_capacity_vector(cfg.moe, T))
    w = jax.random.normal(jax.random.PRNGKey(5),
                          (cfg.d_model, cfg.moe.n_experts), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (T, cfg.d_model))
    r = route(x, w, cfg.moe)
    assert (np.asarray(r.load) <= caps + 1e-9).all()


# ----------------------------------------- _aux_losses edge cases (S3)

def test_aux_loss_all_dropped_no_sentinel_leak():
    """Every slot dropped: the sentinel one-hot column (expert index E)
    must be sliced away, not leak into f — aux comes out exactly 0."""
    T, E = 32, 8
    logits = jax.random.normal(jax.random.PRNGKey(7), (T, E))
    assign = jnp.full((T, 2), -1, jnp.int32)
    aux, z = _aux_losses(logits, assign, E)
    assert float(aux) == 0.0
    assert np.isfinite(float(z))


def test_aux_loss_matches_manual_fraction():
    T, E = 64, 4
    logits = jnp.zeros((T, E))
    assign = jnp.zeros((T, 1), jnp.int32)        # all slots on expert 0
    aux, _ = _aux_losses(logits, assign, E)
    # f = [1,0,0,0], p = 1/E each -> aux = E * 1 * 1/E = 1
    assert float(aux) == pytest.approx(1.0, abs=1e-6)


def test_topk_router_no_overflow_probes():
    """router='topk' must truncate preferences at depth k: every placed
    slot's expert is within the token's top-k gate choices."""
    cfg = _cfg("topk")
    T, k = 128, cfg.moe.top_k
    w = jax.random.normal(jax.random.PRNGKey(8),
                          (cfg.d_model, cfg.moe.n_experts), jnp.float32)
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(9), (T, cfg.d_model))
    r = route(x, w, cfg.moe)
    logits = x @ w
    topk = np.asarray(jax.lax.top_k(logits, k)[1])
    assign = np.asarray(r.assign)
    for t in range(T):
        placed = assign[t][assign[t] >= 0]
        assert set(placed.tolist()) <= set(topk[t].tolist())
