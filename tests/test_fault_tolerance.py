"""runtime.fault_tolerance: crash-mid-write atomicity, heartbeat expiry,
capacity-proportional elastic re-mesh, and the VW state migrator."""
import os
import time

import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import PipelineConfig, ShardedTokenPipeline
from repro.runtime.fault_tolerance import (FaultTolerantRunner, FTConfig,
                                           VWStateMigrator, plan_remesh)


def _pipe(n_hosts=3, per_host=8):
    return ShardedTokenPipeline(PipelineConfig(
        vocab=64, seq_len=8, global_batch=24, n_hosts=n_hosts,
        n_shards_per_host=per_host))


def _runner(tmp_path, n_hosts=3, pipeline=None, capacities=None):
    return FaultTolerantRunner(
        FTConfig(ckpt_dir=str(tmp_path / "ckpt")), n_hosts,
        pipeline=pipeline, capacities=capacities)


# -- checkpointer atomicity contract (.tmp → rename) -----------------------

def test_crash_mid_write_leaves_latest_committed(tmp_path):
    """A stale .tmp directory (crash mid-write) must be invisible to
    latest_step and restore must return the last *committed* tree."""
    d = str(tmp_path / "ckpt")
    tree = {"w": np.arange(10, dtype=np.float32)}
    ckpt.save(d, 10, tree)
    # simulate a crash after the partial write, before the rename
    tmp = os.path.join(d, "step_00000020.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write("{ truncated")
    assert ckpt.latest_step(d) == 10
    got = ckpt.restore(d, 10, {"w": np.zeros(10, np.float32)})
    assert np.array_equal(got["w"], tree["w"])


def test_recommit_overwrites_stale_tmp(tmp_path):
    """A retried save at the same step must clobber the stale .tmp and
    commit cleanly."""
    d = str(tmp_path / "ckpt")
    tmp = os.path.join(d, "step_00000010.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "shard_0.npz"), "w") as f:
        f.write("garbage")
    tree = {"w": np.full(4, 7.0, np.float32)}
    ckpt.save(d, 10, tree)
    assert ckpt.latest_step(d) == 10
    assert not os.path.exists(tmp)
    got = ckpt.restore(d, 10, {"w": np.zeros(4, np.float32)})
    assert np.array_equal(got["w"], tree["w"])


def test_runner_restore_latest_roundtrip(tmp_path):
    ft = _runner(tmp_path)
    tree = {"p": np.arange(6, dtype=np.float32)}
    assert ft.maybe_save(50, tree)          # ckpt_every=50
    assert not ft.maybe_save(51, tree)
    ft.saver.wait()
    step, got = ft.restore_latest({"p": np.zeros(6, np.float32)})
    assert step == 50
    assert np.array_equal(got["p"], tree["p"])


# -- liveness: one marking path, configurable timeout ----------------------

def test_heartbeat_expiry_triggers_remesh(tmp_path):
    pipe = _pipe()
    ft = _runner(tmp_path, pipeline=pipe)
    ft.heartbeat(1)
    ft.heartbeat(2)
    ft.hosts[0].last_heartbeat = time.monotonic() - 10.0
    # per-test timeout override instead of the 300 s config default
    dead = ft.check_failures(timeout_s=1.0)
    assert dead == [0]
    assert not ft.hosts[0].alive
    assert len(pipe.shards_of(0)) == 0
    assert [h for _, h in ft.failures] == [0]


def test_on_failure_idempotent_single_marking_path(tmp_path):
    """Direct on_failure and heartbeat-expiry must take the same path:
    the first call marks + evacuates, any repeat is a no-op."""
    pipe = _pipe()
    ft = _runner(tmp_path, pipeline=pipe)
    moved = ft.on_failure(0)
    assert len(moved) == 8
    assert ft.on_failure(0) == []                    # already dead
    ft.heartbeat(1)
    ft.heartbeat(2)
    assert ft.check_failures(timeout_s=1.0) == []    # not re-declared
    assert len(ft.failures) == 1


def test_evacuation_is_capacity_proportional_not_round_robin(tmp_path):
    """The satellite bugfix: a 3× survivor absorbs the dead host's
    shards, not an even round-robin split."""
    pipe = _pipe()
    ft = _runner(tmp_path, pipeline=pipe, capacities=[1.0, 1.0, 3.0])
    moved = ft.on_failure(0)
    counts = np.bincount(pipe.shard_owner, minlength=3)
    assert counts[0] == 0 and len(moved) == 8
    # round-robin would give 12/12; capacity-proportional target is
    # 24·(1/4)=6 vs 24·(3/4)=18 — all 8 evacuated shards go to host 2
    assert counts.tolist() == [0, 8, 16]


def test_evacuation_uniform_capacities_spreads_evenly(tmp_path):
    pipe = _pipe(n_hosts=4, per_host=4)
    ft = _runner(tmp_path, n_hosts=4, pipeline=pipe)
    ft.on_failure(1)
    counts = np.bincount(pipe.shard_owner, minlength=4)
    assert counts[1] == 0
    assert sorted(counts[[0, 2, 3]].tolist()) == [5, 5, 6]


def test_cascading_failures_leave_no_orphans(tmp_path):
    pipe = _pipe()
    ft = _runner(tmp_path, pipeline=pipe)
    ft.on_failure(0)
    ft.on_failure(2)
    counts = np.bincount(pipe.shard_owner, minlength=3)
    assert counts.tolist() == [0, 24, 0]
    # last host down: nowhere to evacuate, but no crash and no orphan move
    assert ft.on_failure(1) == []


# -- plan_remesh ------------------------------------------------------------

@pytest.mark.parametrize("chips,mp,want", [
    (64, 16, (4, 16)),     # full pool
    (63, 16, (3, 16)),     # shrink: one chip lost drops a data replica
    (16, 16, (1, 16)),     # minimum mesh
    (8, 16, (1, 16)),      # fewer chips than MP degree: clamped floor
    (96, 16, (6, 16)),     # grow
])
def test_plan_remesh_shrink_grow(chips, mp, want):
    assert plan_remesh(chips, mp) == want


# -- VW state migrator ------------------------------------------------------

def test_migrator_roundtrip_and_accounting(tmp_path):
    mig = VWStateMigrator(str(tmp_path / "mig"))
    state = {"kv": np.arange(1000, dtype=np.float32)}
    mig.put(5, state)
    assert mig.state_bytes(5) == 4000.0
    moved = mig.transfer(5, src=0, dst=2)
    assert moved == 4000.0 and mig.bytes_moved == 4000.0
    got = mig.get(5, like={"kv": np.zeros(1000, np.float32)})
    assert np.array_equal(got["kv"], state["kv"])
    assert mig.transfers == [(5, 0, 2)]


def test_migrator_stateless_vw_moves_free(tmp_path):
    mig = VWStateMigrator(str(tmp_path / "mig"))
    assert mig.transfer(3, 0, 1) == 0.0
    assert mig.bytes_moved == 0.0
    assert mig.get(3) is None
    assert mig.transfers == [(3, 0, 1)]


def test_migrator_get_without_like_restores_tree_structure(tmp_path):
    """get(vw) with no template must return the structure last put for
    the VW — a nested dict comes back a nested dict, not a flat leaf
    list (the transfer round-trip depends on this)."""
    mig = VWStateMigrator(str(tmp_path / "mig"))
    tree = {"kv": np.arange(8, dtype=np.float32),
            "meta": {"pos": np.asarray(7, np.int32)}}
    mig.put(3, tree)
    got = mig.get(3)
    assert isinstance(got, dict) and set(got) == {"kv", "meta"}
    np.testing.assert_array_equal(got["kv"], tree["kv"])
    assert int(got["meta"]["pos"]) == 7
    # the transfer path re-commits the same structure
    mig.transfer(3, 0, 1)
    again = mig.get(3)
    assert isinstance(again, dict)
    np.testing.assert_array_equal(again["kv"], tree["kv"])


def test_migrator_versions_are_atomic(tmp_path):
    """Each put commits through .tmp→rename; a stale .tmp from a crashed
    transfer never shadows the committed version."""
    mig = VWStateMigrator(str(tmp_path / "mig"))
    mig.put(1, {"s": np.zeros(4, np.float32)})
    mig.put(1, {"s": np.ones(4, np.float32)})
    vw_dir = os.path.join(str(tmp_path / "mig"), "vw_1")
    os.makedirs(os.path.join(vw_dir, "step_00000099.tmp"))
    got = mig.get(1, like={"s": np.zeros(4, np.float32)})
    assert np.array_equal(got["s"], np.ones(4))
