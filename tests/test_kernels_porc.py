"""porc_assign Pallas kernel vs jnp oracle: shape/dtype sweeps + bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, streams
from repro.kernels.porc_assign import porc_assign
from repro.kernels.ref import ref_porc_assign


@pytest.mark.parametrize("n_bins", [8, 16, 100, 256])
@pytest.mark.parametrize("block", [64, 128])
def test_kernel_matches_ref(n_bins, block):
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), 4096, 1000, 1.3)
    a_ref, l_ref = ref_porc_assign(keys, n_bins, block=block, eps=0.05)
    a_k, l_k = porc_assign(keys, n_bins, block=block, eps=0.05)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_k))


@pytest.mark.parametrize("z", [0.5, 1.0, 1.6])
def test_imbalance_bounded(z):
    n, m, eps = 64, 8192, 0.05
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(2), m, 2000, z)
    a, load = porc_assign(keys, n, eps=eps)
    # capacity bound holds up to block staleness (≤ 1 block per bin)
    assert float(load.max()) <= (1 + eps) * m / n + 128


def test_continuation_equals_one_shot():
    """Routing in two calls with (m0, load0) == one call."""
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(3), 2048, 500, 1.2)
    a_full, l_full = ref_porc_assign(keys, n, eps=0.05)
    a1, l1 = ref_porc_assign(keys[:1024], n, eps=0.05)
    a2, l2 = ref_porc_assign(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(l_full), np.asarray(l2))


def test_pallas_kernel_continuation_equals_one_shot():
    """The Pallas kernel carries (m0, load0) across calls like the ref."""
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(3), 2048, 500, 1.2)
    a_full, l_full = porc_assign(keys, n, eps=0.05)
    a1, l1 = porc_assign(keys[:1024], n, eps=0.05)
    a2, l2 = porc_assign(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(l_full), np.asarray(l2))


# ---------------------------------------------------------------------------
# snapshot-probing fast path (ref_porc_snapshot / ref_porc_route)
# ---------------------------------------------------------------------------

def test_snapshot_b1_equals_sequential_oracle():
    from repro.core import partitioners as P
    from repro.kernels.ref import ref_porc_snapshot
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(6), 3000, 800, 1.3)
    for eps in (0.01, 0.05):
        a_seq = np.asarray(P.power_of_random_choices(keys, 24, eps=eps))
        a_b1, _ = ref_porc_snapshot(keys, 24, block=1, eps=eps)
        np.testing.assert_array_equal(a_seq, np.asarray(a_b1))


@pytest.mark.parametrize("block", [32, 128])
def test_snapshot_envelope_and_conservation(block):
    from repro.kernels.ref import ref_porc_snapshot
    n, m, eps = 64, 8192, 0.05
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(7), m, 2000, 1.4)
    a, load = ref_porc_snapshot(keys, n, block=block, eps=eps)
    assert float(load.max()) <= (1 + eps) * m / n + block
    np.testing.assert_allclose(np.asarray(load),
                               np.asarray(metrics.loads(a, n)))


@pytest.mark.parametrize("m", [0, 1, 127, 128, 301, 1000])
def test_block_spans_cover_stream(m):
    from repro.kernels.ref import block_spans
    spans = block_spans(m, 128)
    covered = 0
    for start, length, blk in spans:
        assert start == covered
        assert length % blk == 0 and 1 <= blk <= 128
        covered += length
    assert covered == m
    # remainder decomposition is bounded: at most log2(block)+1 spans
    assert len(spans) <= 1 + 8


def test_porc_route_state_threading():
    """ref_porc_route: split calls with carried PorcState == one call
    (blocks aligned), and partial blocks route exactly len(keys)."""
    from repro.kernels.ref import ref_porc_route
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(8), 1000, 300, 1.2)
    a_full, s_full = ref_porc_route(keys, n, block=128, eps=0.05)
    a1, s1 = ref_porc_route(keys[:512], n, block=128, eps=0.05)
    a2, s2 = ref_porc_route(keys[512:], n, block=128, eps=0.05, state=s1)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(s_full.load), np.asarray(s2.load))
    assert float(s_full.routed) == float(s2.routed) == 1000.0
    assert float(s_full.load.sum()) == 1000.0


def test_load_equals_histogram():
    n = 16
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(4), 1024, 200, 1.0)
    a, load = porc_assign(keys, n)
    hist = np.asarray(metrics.loads(a, n))
    np.testing.assert_allclose(np.asarray(load), hist)


def test_memory_vs_shuffle():
    """PoRC replication stays well below shuffle grouping."""
    from repro.core import partitioners as P
    n, m = 50, 16384
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(5), m, 1000, 1.2)
    a, _ = porc_assign(keys, n, eps=0.05)
    mem_porc = int(metrics.memory_footprint(a, keys, n, 1000))
    mem_sg = int(metrics.memory_footprint(
        P.shuffle_grouping(keys, n), keys, n, 1000))
    assert mem_porc < 0.6 * mem_sg
