"""porc_assign Pallas kernel vs jnp oracle: shape/dtype sweeps + bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, streams
from repro.kernels.porc_assign import porc_assign
from repro.kernels.ref import ref_porc_assign


@pytest.mark.parametrize("n_bins", [8, 16, 100, 256])
@pytest.mark.parametrize("block", [64, 128])
def test_kernel_matches_ref(n_bins, block):
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), 4096, 1000, 1.3)
    a_ref, l_ref = ref_porc_assign(keys, n_bins, block=block, eps=0.05)
    a_k, l_k = porc_assign(keys, n_bins, block=block, eps=0.05)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_k))


@pytest.mark.parametrize("z", [0.5, 1.0, 1.6])
def test_imbalance_bounded(z):
    n, m, eps = 64, 8192, 0.05
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(2), m, 2000, z)
    a, load = porc_assign(keys, n, eps=eps)
    # capacity bound holds up to block staleness (≤ 1 block per bin)
    assert float(load.max()) <= (1 + eps) * m / n + 128


def test_continuation_equals_one_shot():
    """Routing in two calls with (m0, load0) == one call."""
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(3), 2048, 500, 1.2)
    a_full, l_full = ref_porc_assign(keys, n, eps=0.05)
    a1, l1 = ref_porc_assign(keys[:1024], n, eps=0.05)
    a2, l2 = ref_porc_assign(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(l_full), np.asarray(l2))


def test_load_equals_histogram():
    n = 16
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(4), 1024, 200, 1.0)
    a, load = porc_assign(keys, n)
    hist = np.asarray(metrics.loads(a, n))
    np.testing.assert_allclose(np.asarray(load), hist)


def test_memory_vs_shuffle():
    """PoRC replication stays well below shuffle grouping."""
    from repro.core import partitioners as P
    n, m = 50, 16384
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(5), m, 1000, 1.2)
    a, _ = porc_assign(keys, n, eps=0.05)
    mem_porc = int(metrics.memory_footprint(a, keys, n, 1000))
    mem_sg = int(metrics.memory_footprint(
        P.shuffle_grouping(keys, n), keys, n, 1000))
    assert mem_porc < 0.6 * mem_sg
