"""porc_assign Pallas kernel vs jnp oracle: shape/dtype sweeps + bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, streams
from repro.kernels.porc_assign import porc_assign
from repro.kernels.ref import ref_porc_assign


@pytest.mark.parametrize("n_bins", [8, 16, 100, 256])
@pytest.mark.parametrize("block", [64, 128])
def test_kernel_matches_ref(n_bins, block):
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(1), 4096, 1000, 1.3)
    a_ref, l_ref = ref_porc_assign(keys, n_bins, block=block, eps=0.05)
    a_k, l_k = porc_assign(keys, n_bins, block=block, eps=0.05)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_k))


@pytest.mark.parametrize("z", [0.5, 1.0, 1.6])
def test_imbalance_bounded(z):
    n, m, eps = 64, 8192, 0.05
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(2), m, 2000, z)
    a, load = porc_assign(keys, n, eps=eps)
    # capacity bound holds up to block staleness (≤ 1 block per bin)
    assert float(load.max()) <= (1 + eps) * m / n + 128


def test_continuation_equals_one_shot():
    """Routing in two calls with (m0, load0) == one call."""
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(3), 2048, 500, 1.2)
    a_full, l_full = ref_porc_assign(keys, n, eps=0.05)
    a1, l1 = ref_porc_assign(keys[:1024], n, eps=0.05)
    a2, l2 = ref_porc_assign(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(l_full), np.asarray(l2))


def test_pallas_kernel_continuation_equals_one_shot():
    """The Pallas kernel carries (m0, load0) across calls like the ref."""
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(3), 2048, 500, 1.2)
    a_full, l_full = porc_assign(keys, n, eps=0.05)
    a1, l1 = porc_assign(keys[:1024], n, eps=0.05)
    a2, l2 = porc_assign(keys[1024:], n, eps=0.05, load0=l1, m0=1024.0)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(l_full), np.asarray(l2))


# ---------------------------------------------------------------------------
# snapshot-probing fast path (ref_porc_snapshot / ref_porc_route)
# ---------------------------------------------------------------------------

def test_snapshot_b1_equals_sequential_oracle():
    from repro.core import partitioners as P
    from repro.kernels.ref import ref_porc_snapshot
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(6), 3000, 800, 1.3)
    for eps in (0.01, 0.05):
        a_seq = np.asarray(P.power_of_random_choices(keys, 24, eps=eps))
        a_b1, _ = ref_porc_snapshot(keys, 24, block=1, eps=eps)
        np.testing.assert_array_equal(a_seq, np.asarray(a_b1))


@pytest.mark.parametrize("block", [32, 128])
def test_snapshot_envelope_and_conservation(block):
    from repro.kernels.ref import ref_porc_snapshot
    n, m, eps = 64, 8192, 0.05
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(7), m, 2000, 1.4)
    a, load = ref_porc_snapshot(keys, n, block=block, eps=eps)
    assert float(load.max()) <= (1 + eps) * m / n + block
    np.testing.assert_allclose(np.asarray(load),
                               np.asarray(metrics.loads(a, n)))


@pytest.mark.parametrize("m", [0, 1, 127, 128, 301, 1000])
def test_block_spans_cover_stream(m):
    from repro.kernels.ref import block_spans
    spans = block_spans(m, 128)
    covered = 0
    for start, length, blk in spans:
        assert start == covered
        assert length % blk == 0 and 1 <= blk <= 128
        covered += length
    assert covered == m
    # remainder decomposition is bounded: at most log2(block)+1 spans
    assert len(spans) <= 1 + 8


def test_porc_route_state_threading():
    """ref_porc_route: split calls with carried PorcState == one call
    (blocks aligned), and partial blocks route exactly len(keys)."""
    from repro.kernels.ref import ref_porc_route
    n = 32
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(8), 1000, 300, 1.2)
    a_full, s_full = ref_porc_route(keys, n, block=128, eps=0.05)
    a1, s1 = ref_porc_route(keys[:512], n, block=128, eps=0.05)
    a2, s2 = ref_porc_route(keys[512:], n, block=128, eps=0.05, state=s1)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(s_full.load), np.asarray(s2.load))
    assert float(s_full.routed) == float(s2.routed) == 1000.0
    assert float(s_full.load.sum()) == 1000.0


# ---------------------------------------------------------------------------
# multi-source engine (ref_porc_multisource)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [1, 64, 128])
@pytest.mark.parametrize("engine", ["snapshot", "strict"])
def test_multisource_s1_bit_identical_to_route(block, engine):
    """S=1, sync_every=1 must reproduce ref_porc_route bit-for-bit with
    either per-block engine (incl. a non-block-multiple tail)."""
    from repro.kernels.ref import ref_porc_multisource, ref_porc_route
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(9), 1777, 400, 1.3)
    a_ref, s_ref = ref_porc_route(keys, 24, block=block, eps=0.05,
                                  engine=engine)
    a_ms, s_ms = ref_porc_multisource(keys, 24, 1, sync_every=1, block=block,
                                      eps=0.05, engine=engine)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_ms))
    np.testing.assert_allclose(np.asarray(s_ref.load),
                               np.asarray(s_ms.base + s_ms.delta.sum(0)))
    assert float(s_ref.routed) == float(s_ms.routed)


@pytest.mark.parametrize("n_sources", [3, 10, 100])
@pytest.mark.parametrize("m", [4096, 3001])
def test_multisource_conservation(n_sources, m):
    """Every message lands in exactly one bin and every source's count
    is accounted: base + Σ deltas == assignment histogram == m (holds
    through syncs, partial blocks, and the ragged sub-S tail)."""
    from repro.kernels.ref import ref_porc_multisource
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(10), m, 900, 1.2)
    a, st = ref_porc_multisource(keys, 50, n_sources, sync_every=2,
                                 block=64, eps=0.05)
    a = np.asarray(a)
    assert a.shape == (m,) and a.min() >= 0 and a.max() < 50
    total = np.asarray(st.base + st.delta.sum(0))
    np.testing.assert_allclose(total, np.bincount(a, minlength=50))
    assert float(st.routed) == m
    assert float(total.sum()) == m


def test_multisource_state_carries_across_calls():
    """Two calls with the carried state == one call over the
    concatenation (spans and sync boundaries aligned)."""
    from repro.kernels.ref import ref_porc_multisource
    S, block = 4, 64
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(11), 2048, 500, 1.2)
    a_full, s_full = ref_porc_multisource(keys, 32, S, sync_every=2,
                                          block=block)
    a1, s1 = ref_porc_multisource(keys[:1024], 32, S, sync_every=2,
                                  block=block)
    a2, s2 = ref_porc_multisource(keys[1024:], 32, S, sync_every=2,
                                  block=block, state=s1)
    np.testing.assert_array_equal(np.asarray(a_full),
                                  np.concatenate([a1, a2]))
    np.testing.assert_allclose(np.asarray(s_full.base), np.asarray(s2.base))
    np.testing.assert_allclose(np.asarray(s_full.delta), np.asarray(s2.delta))
    assert float(s_full.routed) == float(s2.routed) == 2048.0


@pytest.mark.parametrize("n_sources", [1, 10, 50, 100])
def test_multisource_imbalance_within_staleness_envelope(n_sources):
    """The Fig 11 claim: as S grows 1→100 the max load stays inside the
    (1+eps) envelope up to one sync window of staleness (the other
    sources' unseen S·sync_every·block messages + the cap lookahead)."""
    from repro.kernels.ref import ref_porc_multisource
    n, m, eps, block, sync_every = 20, 20_000, 0.05, 4, 2
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(12), m, 2000, 1.2)
    a, st = ref_porc_multisource(keys, n, n_sources, sync_every=sync_every,
                                 block=block, eps=eps)
    load = np.asarray(st.base + st.delta.sum(0))
    window = n_sources * sync_every * block
    assert load.max() <= (1 + eps) * m / n + window + 1
    assert load.sum() == m


@pytest.mark.parametrize("n_sources", [5, 32])
def test_multisource_strict_engine_conserves_and_bounds(n_sources):
    """The vmapped rank-sequential engine at S>1: conservation plus the
    strict in-block cap (overshoot bounded by the cross-source sync
    window alone, with no in-block staleness term)."""
    from repro.kernels.ref import ref_porc_multisource
    n, m, eps, block, sync_every = 20, 16_000, 0.05, 8, 2
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(15), m, 1500, 1.3)
    a, st = ref_porc_multisource(keys, n, n_sources, sync_every=sync_every,
                                 block=block, eps=eps, engine="strict")
    a = np.asarray(a)
    load = np.asarray(st.base + st.delta.sum(0))
    np.testing.assert_allclose(load, np.bincount(a, minlength=n))
    assert float(st.routed) == m
    window = n_sources * sync_every * block
    assert load.max() <= (1 + eps) * m / n + window + 1


def test_multisource_sync_phase_carries_across_calls():
    """The sync counter must not restart per call: feeding one block at
    a time with sync_every=4 still merges every 4th block, bit-equal to
    the one-shot stream (and the deltas do eventually publish)."""
    from repro.kernels.ref import ref_porc_multisource
    S, block, sync_every = 4, 16, 4
    step = S * block                      # one scan step per call
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(14),
                                      8 * step, 300, 1.2)
    a_full, s_full = ref_porc_multisource(keys, 16, S,
                                          sync_every=sync_every, block=block)
    st, parts = None, []
    for i in range(8):
        a, st = ref_porc_multisource(keys[i * step:(i + 1) * step], 16, S,
                                     sync_every=sync_every, block=block,
                                     state=st)
        parts.append(a)
    np.testing.assert_array_equal(np.asarray(a_full), np.concatenate(parts))
    np.testing.assert_allclose(np.asarray(s_full.base), np.asarray(st.base))
    np.testing.assert_allclose(np.asarray(s_full.delta), np.asarray(st.delta))
    assert int(st.ticks) == 0             # 8 blocks = 2 full sync periods
    assert float(np.asarray(st.delta).sum()) == 0.0   # deltas published


def test_multisource_empty_stream():
    from repro.kernels.ref import ref_porc_multisource
    a, st = ref_porc_multisource(jnp.zeros((0,), jnp.int32), 8, 4)
    assert a.shape == (0,)
    assert float(st.routed) == 0.0


def test_multisource_tail_only_call():
    """A call shorter than S routes the ragged tail path alone: one
    message per source, the rest masked — no phantom load."""
    from repro.kernels.ref import ref_porc_multisource
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(13), 5, 50, 1.2)
    a, st = ref_porc_multisource(keys, 8, 16, block=64, eps=0.05)
    a = np.asarray(a)
    assert a.shape == (5,) and a.min() >= 0 and a.max() < 8
    total = np.asarray(st.base + st.delta.sum(0))
    np.testing.assert_allclose(total, np.bincount(a, minlength=8))
    assert float(st.routed) == 5.0


def test_load_equals_histogram():
    n = 16
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(4), 1024, 200, 1.0)
    a, load = porc_assign(keys, n)
    hist = np.asarray(metrics.loads(a, n))
    np.testing.assert_allclose(np.asarray(load), hist)


def test_memory_vs_shuffle():
    """PoRC replication stays well below shuffle grouping."""
    from repro.core import partitioners as P
    n, m = 50, 16384
    keys = streams.sample_zipf_stream(jax.random.PRNGKey(5), m, 1000, 1.2)
    a, _ = porc_assign(keys, n, eps=0.05)
    mem_porc = int(metrics.memory_footprint(a, keys, n, 1000))
    mem_sg = int(metrics.memory_footprint(
        P.shuffle_grouping(keys, n), keys, n, 1000))
    assert mem_porc < 0.6 * mem_sg
