"""CGRequestRouter / ServingEngine: batch-vs-sequential equivalence and
rebalance-under-skew regression coverage."""
import numpy as np
import pytest

from repro.serve import CGRequestRouter, ServingEngine


def _zipf_keys(n, seed=0, a=1.4, mod=50):
    rng = np.random.default_rng(seed)
    return (rng.zipf(a, n) % mod).astype(np.int32)


def test_route_batch_b1_matches_sequential_route():
    """route_batch with block_size=1 is bit-identical to a sequence of
    per-message route() calls (the pure-python oracle)."""
    keys = _zipf_keys(500)
    r_seq = CGRequestRouter(4, alpha=8, eps=0.05)
    r_blk = CGRequestRouter(4, alpha=8, eps=0.05, block_size=1)
    seq = np.asarray([r_seq.route(int(k)) for k in keys])
    blk = r_blk.route_batch(keys)
    np.testing.assert_array_equal(seq, blk)
    np.testing.assert_allclose(r_seq.vw_load, r_blk.vw_load)
    assert r_seq.routed == r_blk.routed


def test_route_batch_load_equivalence_blocked():
    """The default (blocked) path must produce the same aggregate load
    profile as sequential routing, up to block staleness per replica.

    block_size=1 is the sequential semantics (bit-identical to route(),
    proven above), so it stands in for the per-message oracle here."""
    m, eps, block = 8000, 0.05, 128
    keys = _zipf_keys(m)
    r_seq = CGRequestRouter(4, alpha=8, eps=eps, block_size=1)
    r_blk = CGRequestRouter(4, alpha=8, eps=eps, block_size=block)
    seq = r_seq.route_batch(keys)
    blk = r_blk.route_batch(keys)
    L_seq = np.bincount(seq, minlength=4).astype(float)
    L_blk = np.bincount(blk, minlength=4).astype(float)
    assert L_blk.sum() == m
    assert r_blk.vw_load.sum() == m       # one VW per message, no phantoms
    # per-VW (1+eps) envelope, up to one block of staleness
    assert r_blk.vw_load.max() <= (1 + eps) * m / r_blk.n_virtual + block
    # replica-level balance matches the sequential profile
    imb_seq = L_seq.max() / L_seq.mean() - 1.0
    imb_blk = L_blk.max() / L_blk.mean() - 1.0
    assert imb_blk <= imb_seq + 0.05, (imb_seq, imb_blk)


def test_route_batch_state_carries_across_calls():
    """Two route_batch calls == one call over the concatenated stream
    (blocks aligned) — the PoRC state must thread through."""
    keys = _zipf_keys(1024)
    r1 = CGRequestRouter(4, alpha=8, eps=0.05, block_size=128)
    r2 = CGRequestRouter(4, alpha=8, eps=0.05, block_size=128)
    a_full = r1.route_batch(keys)
    a_split = np.concatenate([r2.route_batch(keys[:512]),
                              r2.route_batch(keys[512:])])
    np.testing.assert_array_equal(a_full, a_split)
    np.testing.assert_allclose(r1.vw_load, r2.vw_load)


def test_route_batch_partial_block_no_padding_pollution():
    """Odd-length batches must account exactly len(keys) messages —
    no phantom padding keys in the load state."""
    r = CGRequestRouter(4, alpha=8, block_size=128)
    out = r.route_batch(_zipf_keys(301))
    assert out.shape == (301,)
    assert r.routed == 301
    assert r.vw_load.sum() == 301


def test_submit_uses_batch_path_and_matches_oracle():
    """Engine.submit routes through route_batch; a batch of one is one
    block of one, so it must equal the sequential oracle."""
    keys = _zipf_keys(64)
    oracle = CGRequestRouter(3, alpha=4)
    eng = ServingEngine([lambda b: b] * 3, CGRequestRouter(3, alpha=4))
    expect = [oracle.route(int(k)) for k in keys]
    for k in keys:
        eng.submit(int(k), payload=k)
    depths = eng.queue_depths()
    assert sum(depths) == len(keys)
    expect_depths = [expect.count(i) for i in range(3)]
    assert depths == expect_depths


def test_rebalance_under_skew_regression():
    """Skewed replica load must trigger delegation: virtual replicas
    move off the overloaded replica and later waves spread out.

    PoRC alone already spreads a hot *key* across virtual replicas, so
    replica-level skew is injected adversarially: replica 0 starts out
    owning every virtual replica (the worst assignment CG pairing must
    recover from)."""
    r = CGRequestRouter(3, alpha=4, eps=0.05, max_queue=16,
                        queue_hi=0.5, queue_lo=0.25)
    r.vw_owner = np.zeros(r.n_virtual, np.int32)   # device-resident map
    served = [0, 0, 0]

    def mk(i):
        def fn(batch):
            served[i] += len(batch)
        return fn

    eng = ServingEngine([mk(0), mk(1), mk(2)], r, max_batch=4)
    n_waves, wave = 12, 64
    for w in range(n_waves):
        eng.submit_batch(_zipf_keys(wave, seed=w), list(range(wave)))
        eng.step()
    total = sum(served)
    for _ in range(400):
        total += eng.step()
        if total >= n_waves * wave:
            break
    assert total == n_waves * wave
    assert r.moves > 0, "delegation never fired under replica skew"
    # replica 0 must have shed virtual replicas to the idle ones
    assert np.sum(r.vw_owner == 0) < 3 * r.alpha
    assert served[0] < total, "rebalance never moved traffic off replica 0"


def test_route_batch_rebases_near_f32_ceiling():
    """Long-lived routers must rebase their f32 load counters before
    +1.0 saturates at 2^24 (which would freeze hot VWs under the cap)."""
    r = CGRequestRouter(4, alpha=8, block_size=128)
    r.vw_load = 2 ** 23 + np.arange(r.n_virtual, dtype=float)
    r.routed = int(r.vw_load.sum())
    out = r.route_batch(_zipf_keys(1000))
    assert out.shape == (1000,)
    assert r.vw_load.max() < 2 ** 23
    # relative loads preserved: old spread + the new 1000 messages
    assert abs(r.vw_load.sum() -
               (np.arange(r.n_virtual).sum() + 1000)) < 1e-3


def test_route_rebases_near_f32_ceiling():
    """The sequential route() path must rebase too — a long-lived router
    used one key at a time otherwise freezes its counters past 2^24."""
    r = CGRequestRouter(4, alpha=8, block_size=128)
    r.vw_load = 2 ** 23 + np.arange(r.n_virtual, dtype=float)
    r.routed = int(r.vw_load.sum())
    for k in _zipf_keys(64):
        assert 0 <= r.route(int(k)) < 4
    assert r.vw_load.max() < 2 ** 23
    assert abs(r.vw_load.sum() -
               (np.arange(r.n_virtual).sum() + 64)) < 1e-3


def test_route_batch_sharded_matches_unsharded_at_s1():
    """A router with one source lane must behave exactly like the
    (previous) unsharded engine: same assignments, same load state."""
    from repro.kernels.ref import PorcState, ref_porc_route
    import jax.numpy as jnp
    keys = _zipf_keys(1777)
    r = CGRequestRouter(4, alpha=8, eps=0.05, block_size=128, n_sources=1)
    out = r.route_batch(keys)
    a_vw, state = ref_porc_route(jnp.asarray(keys, jnp.int32), r.n_virtual,
                                 block=128, eps=0.05)
    np.testing.assert_array_equal(out, r.vw_owner[np.asarray(a_vw)])
    np.testing.assert_allclose(r.vw_load, np.asarray(state.load))
    assert r.routed == 1777


@pytest.mark.parametrize("n_sources", [4, 16])
def test_route_batch_sharded_conserves_and_balances(n_sources):
    """Sharded lanes account every message exactly once and keep the
    per-VW envelope up to one sync window of staleness."""
    m, eps, block = 8000, 0.05, 16
    r = CGRequestRouter(4, alpha=8, eps=eps, block_size=block,
                        n_sources=n_sources, sync_every=2)
    out = r.route_batch(_zipf_keys(m))
    assert out.shape == (m,)
    assert r.routed == m
    assert abs(r.vw_load.sum() - m) < 1e-3
    window = n_sources * 2 * block
    assert r.vw_load.max() <= (1 + eps) * m / r.n_virtual + window + 1


def test_route_batch_sharded_state_carries_across_calls():
    """Lane deltas must survive between route_batch calls — splitting a
    stream (aligned to S·block and the sync period) changes nothing."""
    keys = _zipf_keys(2048)
    kw = dict(alpha=8, eps=0.05, block_size=16, n_sources=4, sync_every=2)
    r1 = CGRequestRouter(4, **kw)
    r2 = CGRequestRouter(4, **kw)
    a_full = r1.route_batch(keys)
    a_split = np.concatenate([r2.route_batch(keys[:1024]),
                              r2.route_batch(keys[1024:])])
    np.testing.assert_array_equal(a_full, a_split)
    np.testing.assert_allclose(r1.vw_load, r2.vw_load)


def test_rebalance_preserves_vw_population():
    r = CGRequestRouter(4, alpha=4)
    r.route_batch(_zipf_keys(512))
    moved = r.rebalance(busy=[0, 1], idle=[2, 3])
    assert moved == 2
    assert len(r.vw_owner) == 16
    assert set(r.vw_owner) <= set(range(4))


def test_rebalance_pairs_by_severity_order():
    """Most-overloaded must pair with most-idle (§V-B), not zip order:
    with pressure given, replica 1 (worst) sheds its hottest virtual
    replica to replica 3 (most idle)."""
    r = CGRequestRouter(4, alpha=2, rate_decay=1.0)
    r.vw_owner = np.repeat(np.arange(4), 2)
    # virtual replica 3 (owned by replica 1) is the hottest
    r.vw_load = np.array([1, 5, 2, 9, 1, 1, 1, 1], np.float32)
    moved = r.rebalance(busy=[0, 1], idle=[2, 3],
                        pressure=[0.9, 1.7, 0.3, 0.1])
    assert moved == 2
    owner = r.vw_owner
    assert owner[3] == 3          # worst busy → most idle, hottest VW
    assert owner[1] == 2          # second pair: replica 0 → replica 2
    assert np.bincount(owner, minlength=4).sum() == 8


def test_rebalance_owner_map_stays_on_device():
    """The rebalance path must not loop over virtual replicas on the
    host: one jitted engine call updates the device-resident owner map
    (smoke-checked via the router's internal delegation state)."""
    import jax
    r = CGRequestRouter(4, alpha=8)
    r.route_batch(_zipf_keys(2048))
    assert isinstance(r._dstate.vw_owner, jax.Array)
    moved = r.rebalance(busy=[0], idle=[3])
    assert moved == 1
    assert isinstance(r._dstate.vw_owner, jax.Array)


@pytest.mark.parametrize("n_sources", [4, 16])
def test_rebalance_with_sharded_sources(n_sources):
    """Serve-path rebalance with n_sources > 1: the merged lane loads
    (base + unpublished deltas) feed the engine, delegation fires and
    conserves the virtual-replica population."""
    r = CGRequestRouter(3, alpha=4, eps=0.05, block_size=16,
                        n_sources=n_sources, sync_every=2)
    r.vw_owner = np.zeros(r.n_virtual, np.int32)     # adversarial skew
    r.route_batch(_zipf_keys(4096))
    moved = r.rebalance(busy=[0], idle=[1, 2])
    assert moved >= 1
    owner = r.vw_owner
    assert np.bincount(owner, minlength=3).sum() == 12
    assert (owner != 0).sum() == moved
    # lane deltas were folded into the rate update, not lost
    assert abs(r.vw_load.sum() - 4096) < 1e-3


def test_capacity_weighted_router_sheds_proportionally():
    """A capacity_weighted router sheds several virtual replicas from a
    slow busy replica in one rebalance (capacity-proportional budget),
    where the uniform router moves one per pair."""
    kw = dict(alpha=8, eps=0.05, block_size=64)
    r_w = CGRequestRouter(4, capacity_weighted=True, **kw)
    r_u = CGRequestRouter(4, **kw)
    keys = _zipf_keys(4096)
    for r in (r_w, r_u):
        r.vw_owner = np.zeros(r.n_virtual, np.int32)
        r.route_batch(keys)
    caps = [0.3, 1.0, 1.0, 1.0]
    moved_w = r_w.rebalance(busy=[0], idle=[1, 2, 3],
                            pressure=[1.0, 0.1, 0.1, 0.1], capacities=caps)
    moved_u = r_u.rebalance(busy=[0], idle=[1, 2, 3],
                            pressure=[1.0, 0.1, 0.1, 0.1], capacities=caps)
    assert moved_u == 1
    assert moved_w > moved_u
    assert np.bincount(r_w.vw_owner, minlength=4).sum() == r_w.n_virtual


# -- capacity-estimate hysteresis -------------------------------------------

def _saturated_engine(**kw):
    """One replica with a long backlog so every tick is saturated (the
    only ticks that update the capacity estimate)."""
    eng = ServingEngine([lambda b: b], CGRequestRouter(1, alpha=4),
                        max_batch=8, **kw)
    eng.submit_batch(np.arange(128, dtype=np.int32), [None] * 128)
    return eng


def test_capacity_estimate_default_is_plain_ewma():
    """Margins at 0 (default) keep the pre-hysteresis per-tick EWMA
    bit-identically: est ← 0.7·est + 0.3·obs on every saturated tick."""
    eng = _saturated_engine()
    eng.replicas[0].slow_factor = 2.0      # cap 8 → 4
    expect = 8.0
    for _ in range(5):
        eng.step()
        expect = 0.7 * expect + 0.3 * 4.0
        assert eng.capacity_estimates[0] == pytest.approx(expect)


def test_capacity_latch_freezes_below_enter_margin():
    """A deviation under the enter margin never perturbs the estimate —
    the flap a recovering replica's one-off hiccup used to cause."""
    eng = _saturated_engine(capacity_enter_margin=0.6,
                            capacity_exit_margin=0.1)
    eng.replicas[0].slow_factor = 2.0      # obs 4 vs est 8: dev 0.5 < 0.6
    for _ in range(5):
        eng.step()
        assert eng.capacity_estimates[0] == 8.0
    assert not eng._cap_latched[0]


def test_capacity_latch_tracks_real_change_then_releases():
    """A deviation past the enter margin latches; the EWMA then tracks
    to convergence and releases once within the exit margin — after
    which sub-margin wobble is frozen again."""
    eng = _saturated_engine(capacity_enter_margin=0.3,
                            capacity_exit_margin=0.1)
    eng.replicas[0].slow_factor = 2.0      # dev 0.5 > 0.3: latch
    eng.step()
    assert eng._cap_latched[0] or eng.capacity_estimates[0] < 8.0
    for _ in range(12):
        eng.step()
    assert eng.capacity_estimates[0] == pytest.approx(4.0, rel=0.15)
    assert not eng._cap_latched[0]         # converged: released
    frozen = eng.capacity_estimates[0]
    eng.step()                             # obs 4 again: dev < enter
    assert eng.capacity_estimates[0] == frozen
