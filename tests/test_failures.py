"""Failure-aware serving: chaos schedules, at-least-once accounting,
capacity-proportional evacuation, re-admission ramp, migration cost."""
import numpy as np
import pytest

from repro.core import delegation as D
from repro.runtime.chaos import ChaosEvent, ChaosSchedule
from repro.serve.engine import CGRequestRouter, ServingEngine


def _engine(n=4, router=None, **kw):
    router = router or CGRequestRouter(n)
    return ServingEngine([lambda b: b for _ in range(n)], router,
                         max_batch=8, **kw)


def _drive(eng, steps, *, load=24, seed=0, drain=True):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        keys = rng.zipf(1.3, size=load).astype(np.int32) % 512
        eng.submit_batch(keys, list(keys))
        eng.step()
    if drain:
        for _ in range(500):
            if eng.in_flight == 0:
                break
            eng.step()


# -- chaos schedules --------------------------------------------------------

def test_chaos_events_pop_once_in_order():
    s = ChaosSchedule([ChaosEvent(5, "slow", 1, factor=2.0),
                       ChaosEvent(3, "crash", 0)])
    assert s.pop_due(2) == []
    assert [e.kind for e in s.pop_due(5)] == ["crash", "slow"]
    assert s.pop_due(5) == []          # each event fires at most once
    assert s.exhausted
    s.reset()
    assert len(s.pop_due(10)) == 2


def test_chaos_kind_validated():
    with pytest.raises(ValueError):
        ChaosEvent(1, "explode", 0)
    with pytest.raises(ValueError):
        ChaosSchedule.kill_one(0, at=10, recover_at=5)


def test_chaos_random_slow_never_touches_the_down_replica():
    """apply_chaos treats "recover" kind-agnostically, so a slow episode
    overlapping a crash downtime would revive the corpse early: between
    a crash and its paired recover, no other event may target the down
    replica."""
    s = ChaosSchedule.random(7, n_replicas=4, n_steps=3000, p_crash=0.01,
                             p_slow=0.05, mean_downtime=40,
                             mean_slowtime=30)
    assert any(e.kind == "slow" for e in s.events)   # scenario exercised
    down = None
    for e in s.events:                               # sorted by step
        if e.kind == "crash":
            assert down is None                      # one down at a time
            down = e.replica
        elif down is not None and e.replica == down:
            assert e.kind == "recover"
            down = None


def test_chaos_random_is_seed_deterministic():
    a = ChaosSchedule.random(3, n_replicas=8, n_steps=500, p_crash=0.02)
    b = ChaosSchedule.random(3, n_replicas=8, n_steps=500, p_crash=0.02)
    assert a.events == b.events
    assert len(a) > 0
    # crash/recover alternate: at most one replica down at a time
    down = 0
    for e in a.events:
        if e.kind == "crash":
            assert down == 0
            down += 1
        elif e.kind == "recover":
            down = max(0, down - 1)


# -- at-least-once accounting ----------------------------------------------

def test_kill_one_loses_nothing():
    """submitted == served + in_flight at every tick, and a full drain
    ends with zero in flight, zero dropped."""
    eng = _engine(8, chaos=ChaosSchedule.kill_one(3, at=10),
                  heartbeat_timeout_steps=2)
    rng = np.random.default_rng(1)
    for _ in range(40):
        keys = rng.zipf(1.3, size=32).astype(np.int32) % 512
        eng.submit_batch(keys, list(keys))
        eng.step()
        served = sum(r.served for r in eng.replicas)
        assert eng.submitted == served + eng.in_flight
    _drive(eng, 0)
    assert eng.in_flight == 0 and eng.dropped == 0
    assert eng.retried > 0            # the stranded queue was re-routed
    assert eng.evacuations == 1


def test_immediate_detection_when_timeout_zero():
    eng = _engine(4)
    eng.submit_batch(np.arange(16, dtype=np.int32), list(range(16)))
    eng.fail_replica(1)               # heartbeat_timeout_steps=0
    assert eng._dead[1]
    assert len(eng.replicas[1].queue) == 0
    assert not (np.asarray(eng.router.vw_owner) == 1).any()


def test_heartbeat_window_delays_declaration():
    eng = _engine(4, heartbeat_timeout_steps=3)
    eng.fail_replica(1)
    assert not eng._dead[1]           # crashed but not yet declared
    for _ in range(3):
        eng.step()
    assert eng._dead[1]
    assert eng.evacuations == 1


def test_dead_replica_receives_no_assignments():
    eng = _engine(4, chaos=ChaosSchedule.kill_one(2, at=5))
    _drive(eng, 30, drain=False)
    assert len(eng.replicas[2].queue) == 0
    assert not (np.asarray(eng.router.vw_owner) == 2).any()


def test_retry_backoff_is_exponential_and_capped():
    eng = _engine(4, retry_backoff_steps=2, max_retry_backoff_steps=8)
    from repro.serve.engine import Request
    for attempts, want in [(0, 2), (1, 4), (2, 8), (5, 8)]:
        eng._retry.clear()
        eng._schedule_retry(Request(0.0, 0, 7, None, attempts=attempts))
        ready, req = eng._retry[0]
        assert ready == eng.step_idx + want
        assert req.attempts == attempts + 1


def test_request_timeout_requeues_stuck_requests():
    eng = _engine(2, request_timeout_steps=2, retry_backoff_steps=1)
    eng.replicas[0].slow_factor = 1e9          # effectively frozen
    eng.submit_batch(np.zeros(64, np.int32), list(range(64)))
    before = eng.retried
    for _ in range(6):
        eng.step()
    assert eng.retried > before
    served = sum(r.served for r in eng.replicas)
    assert eng.submitted == served + eng.in_flight   # nothing lost


def test_timed_out_retries_get_a_fresh_window_and_drain():
    """The head-of-line timeout measures from the last re-enqueue, not
    the original submit: a burst deep enough that head-of-line wait
    exceeds the timeout must still drain to zero in flight (retries with
    the original tick would time out at every queue head forever)."""
    eng = _engine(2, request_timeout_steps=2, retry_backoff_steps=1)
    eng.submit_batch(np.zeros(64, np.int32), list(range(64)))
    for _ in range(300):
        if eng.in_flight == 0:
            break
        eng.step()
    assert eng.in_flight == 0
    assert sum(r.served for r in eng.replicas) == eng.submitted
    assert eng.dropped == 0


def test_stripped_dead_replica_stops_signalling_busy():
    """Once a declared-dead replica owns zero VWs its busy latch must
    release — a corpse at occupancy 1.0 would rank first in the busy
    queue forever and pollute the severity ordering."""
    eng = _engine(4, chaos=ChaosSchedule.kill_one(2, at=2))
    _drive(eng, 10, drain=False)
    assert not (np.asarray(eng.router.vw_owner) == 2).any()
    rep = eng.replicas[2]
    assert not rep.busy_signal and not rep.idle_signal


# -- recovery ramp ----------------------------------------------------------

def test_recovery_readmits_through_ramp():
    eng = _engine(4, heartbeat_timeout_steps=1, readmit_ramp_steps=10,
                  readmit_floor=0.1)
    eng.fail_replica(1)
    eng.step()
    assert eng._dead[1]
    eng.recover_replica(1)
    assert eng._readmit[1] == pytest.approx(0.1)
    caps = eng._effective_capacities()
    assert caps[1] == pytest.approx(0.1 * max(eng.capacity_estimates[1],
                                              1e-3))
    _drive(eng, 12, drain=False)
    assert eng._readmit[1] == pytest.approx(1.0)


def test_recovered_replica_earns_vws_back():
    eng = _engine(4, chaos=ChaosSchedule.kill_one(1, at=5, recover_at=15),
                  readmit_ramp_steps=5)
    router = eng.router
    # heavy enough that survivors raise busy signals post-recovery
    _drive(eng, 60, load=60, drain=False)
    owner = np.asarray(router.vw_owner)
    assert (owner == 1).any()         # delegation handed VWs back


def test_slowdown_event_shrinks_drain_rate():
    eng = _engine(2, chaos=ChaosSchedule.slowdown(0, at=1, factor=4.0,
                                                  recover_at=50))
    eng.submit_batch(np.zeros(8, np.int32), list(range(8)))
    eng.step()
    # slow replica drains max_batch/4 = 2 per tick instead of 8
    assert eng.replicas[0].slow_factor == 4.0
    assert eng.replicas[0].served <= 2 + 8  # replica 1 may hold others


# -- migration cost on the serving router ----------------------------------

def test_router_accrues_vw_state_bytes():
    r = CGRequestRouter(4, state_bytes_per_request=100.0)
    r.route_batch(np.arange(32, dtype=np.int32))
    assert r.vw_state_bytes is not None
    assert r.vw_state_bytes.sum() == pytest.approx(3200.0)


def test_router_bytes_moved_accounted_on_rebalance():
    r = CGRequestRouter(4, capacity_weighted=True,
                        state_bytes_per_request=10.0)
    eng = _engine(4, router=r)
    _drive(eng, 40, drain=False)
    if r.moves > 0:
        assert r.bytes_moved > 0.0


def test_byte_budget_caps_slot_migration():
    """With a byte budget smaller than one hot VW's state, the metered
    engine must refuse the move the unmetered engine makes."""
    n, V = 2, 4
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                             byte_budget_per_slot=50.0)
    st = D.init_state(cfg)
    vw_bytes = np.full(V, 100.0, np.float32)
    arrivals = np.asarray([10.0, 0, 0, 0], np.float32)
    busy = np.asarray([True, False])
    idle = np.asarray([False, True])
    new, moved = D.rebalance_step(
        cfg, st, np.asarray([1.0, 0.0], np.float32), busy, idle,
        arrivals, np.ones(n, np.float32), None, vw_bytes)
    assert int(moved) == 0
    assert float(new.bytes_moved) == 0.0
    # same scenario unmetered: the move happens
    cfg0 = cfg._replace(byte_budget_per_slot=0.0)
    _, moved0 = D.rebalance_step(
        cfg0, st, np.asarray([1.0, 0.0], np.float32), busy, idle,
        arrivals, np.ones(n, np.float32))
    assert int(moved0) == 1


def test_min_gain_per_byte_gates_cold_vws():
    """Cost-benefit: a cold VW with big state must not move; a hot one
    amortizing its transfer must."""
    n, V = 2, 4
    cfg = D.DelegationConfig(n_workers=n, n_virtual=V,
                             min_gain_per_byte=0.5)
    st = D.init_state(cfg, vw_owner=np.asarray([0, 0, 1, 1], np.int32))
    vw_bytes = np.asarray([100.0, 2.0, 100.0, 100.0], np.float32)
    arrivals = np.asarray([10.0, 5.0, 0.0, 0.0], np.float32)  # vw1: 5 ≥ 1
    busy = np.asarray([True, False])
    idle = np.asarray([False, True])
    new, moved = D.rebalance_step(
        cfg, st, np.asarray([1.0, 0.0], np.float32), busy, idle,
        arrivals, np.ones(n, np.float32), None, vw_bytes)
    assert int(moved) == 1
    owner = np.asarray(new.vw_owner)
    assert owner[1] == 1              # the amortizing VW moved
    assert owner[0] == 0              # the hot-but-heavy one did not
    assert float(new.bytes_moved) == pytest.approx(2.0)


# -- shared evacuation planner ---------------------------------------------

def test_evacuate_capacity_proportional():
    owner = np.repeat(np.arange(3), 4)           # 4 VWs each
    rate = np.ones(12, np.float32)
    new, n_moved, nbytes = D.evacuate(owner, rate, 0, [1.0, 1.0, 3.0])
    assert n_moved == 4 and nbytes == 0.0
    counts = np.bincount(new, minlength=3)
    # targets: 12·(1/4)=3 vs 12·(3/4)=9 → all 4 evacuees go to worker 2
    assert counts.tolist() == [0, 4, 8]


def test_evacuate_accounts_bytes_and_preserves_population():
    owner = np.repeat(np.arange(4), 2)
    rate = np.arange(8, dtype=np.float32)
    vw_bytes = np.full(8, 3.0)
    new, n_moved, nbytes = D.evacuate(owner, rate, [1, 2],
                                      np.ones(4), vw_bytes)
    assert n_moved == 4 and nbytes == pytest.approx(12.0)
    assert not np.isin(new, [1, 2]).any()
    assert len(new) == 8


def test_evacuate_no_survivors_is_noop():
    owner = np.zeros(4, np.int32)
    new, n_moved, nbytes = D.evacuate(owner, np.ones(4), [0], [1.0])
    assert n_moved == 0 and np.array_equal(new, owner)


def test_evacuate_cold_engine_balances_counts():
    """All-zero rates (nothing routed yet) must still spread the dead
    worker's VWs capacity-proportionally by count."""
    owner = np.repeat(np.arange(3), 6)
    new, n_moved, _ = D.evacuate(owner, np.zeros(18), 0, [1.0, 1.0, 2.0])
    counts = np.bincount(new, minlength=3)
    assert n_moved == 6
    assert counts[2] > counts[1] >= 6


# -- defaults-off parity ----------------------------------------------------

def test_armed_but_idle_failure_machinery_is_bit_identical():
    """With chaos wired but no events firing, the owner-map trajectory,
    queue depths and move counts must match the plain engine exactly."""
    def run(**kw):
        r = CGRequestRouter(4, capacity_weighted=True, adaptive_moves=True,
                            hysteresis=True)
        eng = _engine(4, router=r, **kw)
        rng = np.random.default_rng(11)
        traj = []
        for _ in range(60):
            keys = rng.zipf(1.2, size=24).astype(np.int32) % 256
            eng.submit_batch(keys, list(keys))
            eng.step()
            traj.append((tuple(np.asarray(r.vw_owner)),
                         tuple(eng.queue_depths()), r.moves))
        return traj

    plain = run()
    armed = run(chaos=ChaosSchedule([]), heartbeat_timeout_steps=5,
                readmit_ramp_steps=10, retry_backoff_steps=2)
    assert plain == armed
