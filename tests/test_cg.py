import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import stream_len

from repro.core import cg, streams

M = stream_len(200_000, 100_000)
N_KEYS = 5000


@pytest.fixture(scope="module")
def keys():
    return streams.sample_zipf_stream(jax.random.PRNGKey(0), M, N_KEYS, 1.1)


def _caps(n, y, z, rho=0.8):
    # service rates: sum = arrival_rate / rho = 1.25 msgs/unit
    c = streams.heterogeneous_capacities(n, y, z)
    return jnp.asarray(c / rho, jnp.float32)


def test_cg_converges_on_heterogeneous(keys):
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(10, 3, 5.0))
    early = float(np.mean(np.asarray(res.imbalance)[:3]))
    late = float(np.mean(np.asarray(res.imbalance)[-3:]))
    assert late < early, f"no convergence: early {early} late {late}"
    assert int(res.moves) > 0


def test_vw_population_conserved(keys):
    """Pairing keeps the virtual-worker count per system constant."""
    cfg = cg.CGConfig(n_workers=8, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(8, 2, 4.0))
    owners = np.asarray(res.state.vw_owner)
    assert owners.shape == (80,)
    assert owners.min() >= 0 and owners.max() < 8


def test_assignment_valid_and_complete(keys):
    cfg = cg.CGConfig(n_workers=10, alpha=10, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(10, 1, 1.0))
    a = np.asarray(res.assignment)
    assert a.shape == (M,)
    assert a.min() >= 0 and a.max() < 10
    vw = np.asarray(res.vw_assignment)
    assert vw.min() >= 0 and vw.max() < 100


def test_cg_beats_kg_on_heterogeneous(keys):
    from repro.core import partitioners as P, simulation
    n = 10
    caps = _caps(n, 3, 5.0)
    cfg = cg.CGConfig(n_workers=n, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, caps)
    kg = simulation.simulate_queues(
        P.key_grouping(keys, n), caps, n, 10_000)
    # steady-state latency spread: CG flat, KG diverging (Fig 10)
    assert float(res.latency_spread[-1]) < float(kg.latency_spread[-1])
    assert float(res.imbalance[-1]) < float(kg.imbalance[-1])


def test_cg_adapts_to_capacity_change(keys):
    """Fig 13: resources change mid-stream; CG re-converges."""
    n = 10
    slot = 4000
    slots = M // slot
    sched = streams.dynamic_capacity_schedule(n, M)
    caps = np.zeros((slots, n))
    for start, c in sched:
        caps[start // slot:] = c / 0.8
    cfg = cg.CGConfig(n_workers=n, alpha=10, eps=0.01, slot_len=slot,
                      max_moves_per_slot=8)
    res = cg.run(cfg, keys, jnp.asarray(caps, jnp.float32))
    imb = np.asarray(res.imbalance)
    third = slots // 3
    # the spike right after the last change decays by the end
    spike = np.mean(imb[2 * third + 1: 2 * third + 4])
    settled = np.mean(imb[-3:])
    assert settled < spike, (spike, settled)
    assert int(res.moves) >= 10


def test_inner_scheme_variants(keys):
    for inner in ("PORC", "KG", "SG"):
        cfg = cg.CGConfig(n_workers=6, alpha=5, slot_len=10_000, inner=inner)
        res = cg.run(cfg, keys[:100_000], _caps(6, 1, 1.0))
        assert np.asarray(res.assignment).max() < 6


# ---------------------------------------------------------------------------
# block-parallel routing path (CGConfig.block_size)
# ---------------------------------------------------------------------------

def test_block_path_b1_bit_identical_to_oracle(keys):
    """block_size=1 must reproduce the per-message oracle bit-for-bit."""
    sub = keys[:30_000]
    caps = _caps(10, 3, 5.0)
    cfg0 = cg.CGConfig(n_workers=10, slot_len=10_000, block_size=0)
    cfg1 = cg.CGConfig(n_workers=10, slot_len=10_000, block_size=1)
    r0, r1 = cg.run(cfg0, sub, caps), cg.run(cfg1, sub, caps)
    np.testing.assert_array_equal(np.asarray(r0.assignment),
                                  np.asarray(r1.assignment))
    np.testing.assert_array_equal(np.asarray(r0.vw_assignment),
                                  np.asarray(r1.vw_assignment))
    np.testing.assert_allclose(np.asarray(r0.state.vw_load),
                               np.asarray(r1.state.vw_load))
    assert int(r0.moves) == int(r1.moves)


@pytest.mark.parametrize("block_size", [64, 128, 1024])
def test_block_path_divergence_bounded(keys, block_size):
    """For B>1 the VW loads must stay inside the paper's (1+eps)
    capacity envelope, up to one block of staleness per bin."""
    eps = 0.05
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=eps, slot_len=10_000,
                      block_size=block_size)
    res = cg.run(cfg, keys, _caps(10, 1, 1.0))
    vw_load = np.asarray(res.state.vw_load)
    V = cfg.n_workers * cfg.alpha
    assert vw_load.max() <= (1 + eps) * len(keys) / V + block_size
    assert vw_load.sum() == len(keys)            # every message placed


def test_block_path_converges_like_oracle(keys):
    """The fast path must keep CG's qualitative behavior: imbalance
    decays on a heterogeneous cluster as pairing kicks in."""
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=0.01, slot_len=10_000,
                      block_size=128)
    res = cg.run(cfg, keys, _caps(10, 3, 5.0))
    early = float(np.mean(np.asarray(res.imbalance)[:3]))
    late = float(np.mean(np.asarray(res.imbalance)[-3:]))
    assert late < early
    assert int(res.moves) > 0


# ---------------------------------------------------------------------------
# distributed sources (CGConfig.n_sources / sync_every)
# ---------------------------------------------------------------------------

def test_multisource_s1_bit_identical_to_single(keys):
    """n_sources=1 must keep the single-source block path bit-for-bit
    (it routes through the same code path, not the multisource one)."""
    sub = keys[:30_000]
    caps = _caps(10, 3, 5.0)
    cfg1 = cg.CGConfig(n_workers=10, slot_len=10_000, block_size=128)
    cfgS = cg.CGConfig(n_workers=10, slot_len=10_000, block_size=128,
                       n_sources=1, sync_every=4)
    r1, rS = cg.run(cfg1, sub, caps), cg.run(cfgS, sub, caps)
    np.testing.assert_array_equal(np.asarray(r1.vw_assignment),
                                  np.asarray(rS.vw_assignment))


@pytest.mark.parametrize("n_sources", [10, 100])
def test_multisource_divergence_bounded(keys, n_sources):
    """With S sources the VW loads stay inside the (1+eps) envelope up
    to one sync window of staleness — the Fig 11 flatness claim inside
    the full CG simulation."""
    eps, block, sync_every = 0.05, 8, 2
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=eps, slot_len=10_000,
                      block_size=block, n_sources=n_sources,
                      sync_every=sync_every)
    res = cg.run(cfg, keys, _caps(10, 1, 1.0))
    vw_load = np.asarray(res.state.vw_load)
    V = cfg.n_workers * cfg.alpha
    window = n_sources * sync_every * block
    assert vw_load.max() <= (1 + eps) * len(keys) / V + window + 1
    assert vw_load.sum() == len(keys)            # every message placed


def test_multisource_converges_on_heterogeneous(keys):
    """Delegation still converges when routing is sharded over sources."""
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=0.01, slot_len=10_000,
                      block_size=16, n_sources=10)
    res = cg.run(cfg, keys, _caps(10, 3, 5.0))
    early = float(np.mean(np.asarray(res.imbalance)[:3]))
    late = float(np.mean(np.asarray(res.imbalance)[-3:]))
    assert late < early
    assert int(res.moves) > 0


def test_multisource_requires_block_path(keys):
    cfg = cg.CGConfig(n_workers=4, slot_len=10_000, block_size=0,
                      n_sources=4)
    with pytest.raises(ValueError):
        cg.run(cfg, keys[:10_000], _caps(4, 1, 1.0))


# ---------------------------------------------------------------------------
# SG round-robin pointer (exact int32, not the f32 t_offset)
# ---------------------------------------------------------------------------

def test_sg_pointer_exact_over_full_stream(keys):
    """inner=SG is a global round-robin: the VW sequence must be exactly
    arange(m) % V with no drift across slot boundaries."""
    cfg = cg.CGConfig(n_workers=6, alpha=5, slot_len=10_000, inner="SG")
    m = 100_000
    res = cg.run(cfg, keys[:m], _caps(6, 1, 1.0))
    np.testing.assert_array_equal(np.asarray(res.vw_assignment),
                                  np.arange(m, dtype=np.int64) % 30)
    assert int(res.state.sg_ptr) == m % 30


def test_sg_pointer_survives_f32_clock_saturation(keys):
    """Past 2^24 routed messages the f32 t_offset cannot advance by
    slot_len·k exactly; the int32 sg_ptr must keep the round-robin
    exact. Simulated by continuing from a state whose clock sits at the
    f32 precision edge."""
    cfg = cg.CGConfig(n_workers=6, alpha=5, slot_len=10_000, inner="SG")
    V = 30
    big = 2.0 ** 24                     # t_offset += 10_000 is inexact here
    state = cg.init_state(cfg)._replace(
        t_offset=jnp.float32(big), sg_ptr=jnp.int32(7))
    res = cg.run(cfg, keys[:20_000], _caps(6, 1, 1.0), state)
    np.testing.assert_array_equal(
        np.asarray(res.vw_assignment),
        (7 + np.arange(20_000, dtype=np.int64)) % V)
    assert int(res.state.sg_ptr) == (7 + 20_000) % V


# ---------------------------------------------------------------------------
# run(..., state=...) continuation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inner", ["PORC", "SG"])
def test_run_state_continuation_matches_single_run(keys, inner):
    """Two runs chained through ``state`` must equal one run over the
    concatenated stream (routing loads, owner map, queues, delegation
    FCFS queues and the SG pointer all carry over)."""
    sub = keys[:60_000]
    caps = _caps(10, 3, 5.0)
    cfg = cg.CGConfig(n_workers=10, slot_len=10_000, inner=inner,
                      capacity_weighted=True, rate_decay=0.5,
                      fcfs_pairing=True)
    full = cg.run(cfg, sub, caps)
    r1 = cg.run(cfg, sub[:30_000], caps)
    r2 = cg.run(cfg, sub[30_000:], caps, r1.state)
    np.testing.assert_array_equal(
        np.asarray(full.assignment),
        np.concatenate([np.asarray(r1.assignment), np.asarray(r2.assignment)]))
    np.testing.assert_allclose(np.asarray(full.state.vw_load),
                               np.asarray(r2.state.vw_load))
    np.testing.assert_array_equal(np.asarray(full.state.vw_owner),
                                  np.asarray(r2.state.vw_owner))
    assert int(full.moves) == int(r2.moves)


# ---------------------------------------------------------------------------
# capacity-weighted delegation (the shared engine inside the simulator)
# ---------------------------------------------------------------------------

def test_capacity_weighted_conserves_vw_population(keys):
    cfg = cg.CGConfig(n_workers=8, alpha=10, eps=0.01, slot_len=10_000,
                      capacity_weighted=True, rate_decay=0.6,
                      fcfs_pairing=True, max_moves_per_slot=16)
    res = cg.run(cfg, keys, _caps(8, 2, 4.0))
    owners = np.asarray(res.state.vw_owner)
    assert owners.shape == (80,)
    assert owners.min() >= 0 and owners.max() < 8
    assert np.bincount(owners, minlength=8).sum() == 80
    assert int(res.moves) > 0


def test_capacity_weighted_converges_to_capacity_shares(keys):
    """On a static heterogeneous cluster the weighted engine re-homes
    VWs until ownership ≈ capacity shares — within a few slots, not one
    VW per slot."""
    n, alpha = 10, 20
    caps = _caps(n, 3, 5.0)
    cfg = cg.CGConfig(n_workers=n, alpha=alpha, eps=0.01, slot_len=10_000,
                      capacity_weighted=True, rate_decay=0.6,
                      fcfs_pairing=True, max_moves_per_slot=16)
    res = cg.run(cfg, keys[:100_000], caps)    # 10 slots
    counts = np.bincount(np.asarray(res.state.vw_owner), minlength=n)
    share = np.asarray(caps) / float(np.asarray(caps).sum())
    np.testing.assert_allclose(counts, share * n * alpha, atol=2.5)
    # uniform pairing cannot have moved enough VWs by then: ideal needs
    # ~3*(45-20)=75 rebalancing moves, one-per-pair does ≤3/slot here
    res_u = cg.run(cfg._replace(capacity_weighted=False, rate_decay=1.0,
                                fcfs_pairing=False), keys[:100_000], caps)
    counts_u = np.bincount(np.asarray(res_u.state.vw_owner), minlength=n)
    err_w = np.abs(counts - share * n * alpha).max()
    err_u = np.abs(counts_u - share * n * alpha).max()
    assert err_w < err_u, (err_w, err_u)


def test_capacity_weighted_tracks_time_varying_capacity(keys):
    """Fig 12/13 shape: capacities change at ⅓ and ⅔; the windowed-rate
    weighted engine re-converges after each change and settles below
    the post-change spike."""
    n = 10
    slot = 4000
    slots = M // slot
    sched = streams.dynamic_capacity_schedule(n, M)
    caps = np.zeros((slots, n))
    for start, c in sched:
        caps[start // slot:] = c / 0.8
    cfg = cg.CGConfig(n_workers=n, alpha=20, eps=0.01, slot_len=slot,
                      max_moves_per_slot=16, capacity_weighted=True,
                      rate_decay=0.6, fcfs_pairing=True)
    res = cg.run(cfg, keys, jnp.asarray(caps, jnp.float32))
    imb = np.asarray(res.imbalance)
    third = slots // 3
    spike = np.mean(imb[2 * third: 2 * third + 3])
    settled = np.mean(imb[-3:])
    assert settled < spike, (spike, settled)
    # and it must also beat the uniform (seed) pairing's settled level
    res_u = cg.run(cfg._replace(capacity_weighted=False, rate_decay=1.0,
                                fcfs_pairing=False),
                   keys, jnp.asarray(caps, jnp.float32))
    settled_u = np.mean(np.asarray(res_u.imbalance)[-3:])
    assert settled < settled_u, (settled, settled_u)
