import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cg, streams

M = 200_000
N_KEYS = 5000


@pytest.fixture(scope="module")
def keys():
    return streams.sample_zipf_stream(jax.random.PRNGKey(0), M, N_KEYS, 1.1)


def _caps(n, y, z, rho=0.8):
    # service rates: sum = arrival_rate / rho = 1.25 msgs/unit
    c = streams.heterogeneous_capacities(n, y, z)
    return jnp.asarray(c / rho, jnp.float32)


def test_cg_converges_on_heterogeneous(keys):
    cfg = cg.CGConfig(n_workers=10, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(10, 3, 5.0))
    early = float(np.mean(np.asarray(res.imbalance)[:3]))
    late = float(np.mean(np.asarray(res.imbalance)[-3:]))
    assert late < early, f"no convergence: early {early} late {late}"
    assert int(res.moves) > 0


def test_vw_population_conserved(keys):
    """Pairing keeps the virtual-worker count per system constant."""
    cfg = cg.CGConfig(n_workers=8, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(8, 2, 4.0))
    owners = np.asarray(res.state.vw_owner)
    assert owners.shape == (80,)
    assert owners.min() >= 0 and owners.max() < 8


def test_assignment_valid_and_complete(keys):
    cfg = cg.CGConfig(n_workers=10, alpha=10, slot_len=10_000)
    res = cg.run(cfg, keys, _caps(10, 1, 1.0))
    a = np.asarray(res.assignment)
    assert a.shape == (M,)
    assert a.min() >= 0 and a.max() < 10
    vw = np.asarray(res.vw_assignment)
    assert vw.min() >= 0 and vw.max() < 100


def test_cg_beats_kg_on_heterogeneous(keys):
    from repro.core import partitioners as P, simulation
    n = 10
    caps = _caps(n, 3, 5.0)
    cfg = cg.CGConfig(n_workers=n, alpha=10, eps=0.01, slot_len=10_000)
    res = cg.run(cfg, keys, caps)
    kg = simulation.simulate_queues(
        P.key_grouping(keys, n), caps, n, 10_000)
    # steady-state latency spread: CG flat, KG diverging (Fig 10)
    assert float(res.latency_spread[-1]) < float(kg.latency_spread[-1])
    assert float(res.imbalance[-1]) < float(kg.imbalance[-1])


def test_cg_adapts_to_capacity_change(keys):
    """Fig 13: resources change mid-stream; CG re-converges."""
    n = 10
    slot = 4000
    slots = M // slot
    sched = streams.dynamic_capacity_schedule(n, M)
    caps = np.zeros((slots, n))
    for start, c in sched:
        caps[start // slot:] = c / 0.8
    cfg = cg.CGConfig(n_workers=n, alpha=10, eps=0.01, slot_len=slot,
                      max_moves_per_slot=8)
    res = cg.run(cfg, keys, jnp.asarray(caps, jnp.float32))
    imb = np.asarray(res.imbalance)
    third = slots // 3
    # the spike right after the last change decays by the end
    spike = np.mean(imb[2 * third + 1: 2 * third + 4])
    settled = np.mean(imb[-3:])
    assert settled < spike, (spike, settled)
    assert int(res.moves) >= 10


def test_inner_scheme_variants(keys):
    for inner in ("PORC", "KG", "SG"):
        cfg = cg.CGConfig(n_workers=6, alpha=5, slot_len=10_000, inner=inner)
        res = cg.run(cfg, keys[:100_000], _caps(6, 1, 1.0))
        assert np.asarray(res.assignment).max() < 6
