"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import metrics, partitioners as P
from repro.kernels.ref import ref_cg_dispatch, ref_porc_assign

SETTINGS = dict(max_examples=20, deadline=None)


@given(st.integers(2, 64), st.integers(0, 2**31 - 1),
       st.floats(0.01, 0.5))
@settings(**SETTINGS)
def test_porc_capacity_invariant(n_bins, seed, eps):
    """∀ streams: PoRC sequential load ≤ (1+eps)·m/n + 1."""
    m = 1024
    keys = jax.random.randint(jax.random.PRNGKey(seed), (m,), 0, 100)
    a = P.power_of_random_choices(keys, n_bins, eps=round(eps, 3))
    L = np.asarray(metrics.loads(a, n_bins))
    assert L.max() <= (1 + eps) * m / n_bins + 1
    assert L.sum() == m                      # every message placed


@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_kernel_equals_ref_random(n_bins, seed):
    keys = jax.random.randint(jax.random.PRNGKey(seed), (512,), 0, 200)
    from repro.kernels.porc_assign import porc_assign
    a_ref, l_ref = ref_porc_assign(keys, n_bins, block=128, eps=0.05)
    a_k, l_k = porc_assign(keys, n_bins, block=128, eps=0.05)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_k))


@given(st.integers(0, 2**31 - 1), st.integers(1, 4),
       st.floats(1.05, 2.0))
@settings(**SETTINGS)
def test_dispatch_conservation(seed, k, cf):
    """Placed slots == total expert load; capacity never exceeded."""
    T, E, D = 256, 8, 6
    r1, r2 = jax.random.split(jax.random.PRNGKey(seed))
    probs = jax.nn.softmax(
        jax.random.normal(r1, (T, E)) + 3 * jax.random.normal(r2, (1, E)), -1)
    gates, pref = jax.lax.top_k(probs, D)
    cap = max(1, int(cf * T * k / E))
    assign, slot, wts, load = ref_cg_dispatch(
        pref.astype(jnp.int32), gates, n_experts=E, k=k, capacity=cap)
    assign, slot, load = map(np.asarray, (assign, slot, load))
    assert load.max() <= cap
    assert (assign >= 0).sum() == load.sum()
    valid = assign >= 0
    pairs = assign[valid] * 100_000 + slot[valid]
    assert len(np.unique(pairs)) == valid.sum()
    # a token never gets the same expert twice
    for t in range(0, T, 37):
        ex = assign[t][assign[t] >= 0]
        assert len(np.unique(ex)) == len(ex)


@given(st.integers(2, 40), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_imbalance_nonnegative(n, seed):
    a = jax.random.randint(jax.random.PRNGKey(seed), (500,), 0, n)
    caps = jnp.ones(n) / n
    assert float(metrics.imbalance(a, caps)) >= -1e-5


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_memory_bounds(seed):
    """unique_keys ≤ memory footprint ≤ min(m, unique·n)."""
    n, n_keys = 8, 50
    keys = jax.random.randint(jax.random.PRNGKey(seed), (400,), 0, n_keys)
    a = P.shuffle_grouping(keys, n)
    mem = int(metrics.memory_footprint(a, keys, n, n_keys))
    uniq = len(np.unique(np.asarray(keys)))
    assert uniq <= mem <= min(400, uniq * n)


@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_greedy_d_imbalance_decreases_in_d(d, seed):
    """More choices → (weakly) better balance, PoTC-style."""
    keys = jax.random.randint(jax.random.PRNGKey(seed), (2000,), 0, 500)
    n = 16
    caps = jnp.ones(n) / n
    i1 = float(metrics.normalized_imbalance(
        P.greedy_d(keys, n, d=1, on_message_id=True), caps))
    id_ = float(metrics.normalized_imbalance(
        P.greedy_d(keys, n, d=d, on_message_id=True), caps))
    assert id_ <= i1 + 1e-6


@given(st.integers(2, 6), st.integers(64, 512), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_hh_sketch_recall_property(depth, width, seed):
    """∀ zipf streams and sketch geometries: count-min never
    underestimates, and the top key is always recalled as heavy once the
    stream mass clears the collision noise (≤ m/width per row)."""
    from repro.core.streams import sample_zipf_stream
    from repro.kernels.ref import (HHPolicy, hh_sketch_init,
                                   hh_sketch_query, hh_sketch_update)
    m = 4096
    keys = sample_zipf_stream(jax.random.PRNGKey(seed), m, 2000, 1.5)
    pol = HHPolicy(depth=depth, width=width)
    counts = hh_sketch_update(pol, hh_sketch_init(pol), keys)
    uniq, true = np.unique(np.asarray(keys), return_counts=True)
    est = np.asarray(hh_sketch_query(pol, counts, jnp.asarray(uniq)))
    assert (est >= true).all()
    assert (est <= true + m / width + 1e-6).all()
    top = int(np.argmax(true))
    assert est[top] >= true[top] >= m / 50      # the head is unmissable
