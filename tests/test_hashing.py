import jax.numpy as jnp
import numpy as np

from repro.core.hashing import (candidate_bins, hash_to_bins, hash_u32,
                                hash_unit_interval)


def test_deterministic():
    k = jnp.arange(1000, dtype=jnp.int32)
    a = np.asarray(hash_u32(k, 1))
    b = np.asarray(hash_u32(k, 1))
    assert np.array_equal(a, b)


def test_salt_independence():
    k = jnp.arange(1000, dtype=jnp.int32)
    a = np.asarray(hash_to_bins(k, 1, 64))
    b = np.asarray(hash_to_bins(k, 2, 64))
    assert not np.array_equal(a, b)
    # different salts should agree only ~1/64 of the time
    assert (a == b).mean() < 0.10


def test_range():
    k = jnp.arange(10_000, dtype=jnp.int32)
    for n in (2, 7, 64, 1000):
        h = np.asarray(hash_to_bins(k, 3, n))
        assert h.min() >= 0 and h.max() < n


def test_uniformity():
    k = jnp.arange(100_000, dtype=jnp.int32)
    h = np.asarray(hash_to_bins(k, 5, 100))
    counts = np.bincount(h, minlength=100)
    # each bin expects 1000; allow ±15%
    assert counts.min() > 850 and counts.max() < 1150


def test_unit_interval():
    k = jnp.arange(10_000, dtype=jnp.int32)
    u = np.asarray(hash_unit_interval(k, 1))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.02


def test_candidate_bins_matches_salts():
    k = jnp.arange(100, dtype=jnp.int32)
    cand = np.asarray(candidate_bins(k, 4, 50))
    for i in range(4):
        expect = np.asarray(hash_to_bins(k, i + 1, 50))
        assert np.array_equal(cand[:, i], expect)
