"""Test hygiene: reset the global activation-sharding rules between
tests so mesh-installing tests (dryrun) don't leak into model tests."""
import pytest

from repro.models.layers import set_act_sharding


@pytest.fixture(autouse=True)
def _reset_act_rules():
    yield
    set_act_sharding({})
