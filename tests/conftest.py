"""Test hygiene and shared stream sizing.

* Resets the global activation-sharding rules between tests so
  mesh-installing tests (dryrun) don't leak into model tests.
* ``stream_len`` scales the synthetic key streams: the default tier-1
  run uses reduced streams so the suite stays fast; set
  ``REPRO_TEST_FULL_STREAMS=1`` (CI does this on main) to run the
  paper-scale lengths.
"""
import os

import pytest

from repro.models.layers import set_act_sharding

FULL_STREAMS = os.environ.get("REPRO_TEST_FULL_STREAMS", "") == "1"


def stream_len(full: int, small: int) -> int:
    """Pick the stream length for the current tier: ``small`` by
    default, ``full`` when REPRO_TEST_FULL_STREAMS=1."""
    return full if FULL_STREAMS else small


@pytest.fixture(autouse=True)
def _reset_act_rules():
    yield
    set_act_sharding({})
