"""Per-architecture smoke: reduced config, one forward/train step on CPU,
shape + finiteness assertions; prefill↔decode cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_zoo as zoo

B, S = 2, 64


def _batch(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(
                    key, (B, cfg.n_patches, cfg.vision_dim), jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, S - cfg.n_patches),
                                             0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_loss_finite(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    loss = jax.jit(lambda p, b: zoo.loss_fn(p, cfg, b))(
        params, _batch(cfg, key))
    assert np.isfinite(float(loss))
    # random-init CE ≈ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_reduces_loss(arch):
    from repro import optim
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    opt = optim.init(params)
    ocfg = optim.AdamWConfig(lr_peak=3e-3, warmup_steps=1, total_steps=10,
                             weight_decay=0.0)
    batch = _batch(cfg, key)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda q: zoo.loss_fn(q, cfg, batch))(p)
        p, o, _ = optim.update(p, g, o, ocfg)
        return p, o, loss

    losses = []
    for _ in range(4):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    cache = zoo.init_cache(cfg, B, 32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache2 = jax.jit(
        lambda p, c, t: zoo.decode_step(p, cfg, c, t))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["gemma3-1b", "internlm2-20b",
                                  "qwen3-moe-235b-a22b", "mamba2-130m",
                                  "zamba2-2.7b", "internvl2-2b"])
def test_prefill_then_decode_consistency(arch):
    """prefill(prompt) ≡ prefill(prompt[:-1]) + decode(prompt[-1]).

    This validates the KV-cache/recurrent-state priming end to end.
    """
    import dataclasses
    cfg = configs.get_smoke_config(arch)
    if cfg.family == "moe":
        # remove routing contention: capacity-bounded prefill vs
        # uncontended decode legitimately route overflow slots
        # differently (the CG semantics); here we test cache priming.
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=64.0))
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    batch = _batch(cfg, key)
    full = dict(batch)
    logits_full, _ = jax.jit(
        lambda p, b: zoo.prefill_step(p, cfg, b))(params, full)

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    last = batch["tokens"][:, -1:]
    _, cache = jax.jit(
        lambda p, b: zoo.prefill_step(p, cfg, b, pad_to=S))(params, short)
    logits_inc, _ = jax.jit(
        lambda p, c, t: zoo.decode_step(p, cfg, c, t))(params, cache, last)

    a = np.asarray(logits_full, np.float32)
    b = np.asarray(logits_inc, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 5e-2, f"prefill/decode mismatch rel={rel}"


def test_longctx_cache_gemma3():
    """gemma3 long-context decode path: ring-buffer local caches."""
    cfg = configs.get_smoke_config("gemma3-1b")
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    from repro.models import transformer
    cache = transformer.init_longctx_cache(cfg, B, 128)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, c, t: zoo.decode_step(p, cfg, c, t))
    for i in range(cfg.sliding_window + 4):   # wrap the ring buffer
        logits, cache = step(params, cache, tok)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["pos"]) == cfg.sliding_window + 4


def test_longctx_matches_uniform_decode():
    """Ring-buffer decode ≡ uniform-cache decode for gemma3."""
    cfg = configs.get_smoke_config("gemma3-1b")
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    from repro.models import transformer
    c_ring = transformer.init_longctx_cache(cfg, B, 64)
    c_uni = zoo.init_cache(cfg, B, 64)
    ring = jax.jit(lambda p, c, t: transformer.decode_step_longctx(p, cfg, c, t))
    uni = jax.jit(lambda p, c, t: transformer.decode_step(p, cfg, c, t))
    toks = jax.random.randint(key, (20, B, 1), 0, cfg.vocab)
    for i in range(20):
        lr, c_ring = ring(params, c_ring, toks[i])
        lu, c_uni = uni(params, c_uni, toks[i])
    rel = (np.abs(np.asarray(lr) - np.asarray(lu)).max()
           / (np.abs(np.asarray(lu)).max() + 1e-9))
    assert rel < 2e-2, rel
