import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partitioners as P, simulation, streams

M = 100_000
N = 10
SLOT = 10_000


def _keys(z=1.1):
    return streams.sample_zipf_stream(jax.random.PRNGKey(0), M, 3000, z)


def test_sg_stable_on_homogeneous():
    caps = jnp.full((N,), 1.25 / N)     # rho = 0.8
    res = simulation.simulate_queues(P.shuffle_grouping(_keys(), N),
                                     caps, N, SLOT)
    assert float(res.queue_spread[-1]) <= 1.0
    assert float(res.imbalance[-1]) < 0.01


def test_kg_diverges_on_skew():
    caps = jnp.full((N,), 1.25 / N)
    res = simulation.simulate_queues(P.key_grouping(_keys(1.4), N),
                                     caps, N, SLOT)
    qs = np.asarray(res.queue_spread)
    assert qs[-1] > qs[0]
    assert qs[-1] > 1000       # hot worker's queue grows without bound


def test_throughput_capped_by_capacity():
    caps = jnp.full((N,), 0.05)         # total service 0.5 < arrival 1.0
    res = simulation.simulate_queues(P.shuffle_grouping(_keys(), N),
                                     caps, N, SLOT)
    thr = np.asarray(res.throughput)
    assert np.all(thr <= 0.5 + 1e-6)


def test_queue_conservation():
    """Σ drained + final queues == arrivals."""
    caps = jnp.full((N,), 1.0 / N)      # rho = 1.0 exactly
    a = P.key_grouping(_keys(1.2), N)
    res = simulation.simulate_queues(a, caps, N, SLOT)
    drained = float(np.sum(np.asarray(res.throughput)) * SLOT)
    final_q = float(np.sum(np.asarray(res.final_queues)))
    assert abs(drained + final_q - M) < 1.0


def test_deployment_hetero_throughput():
    """Fig 15: under global backpressure, capacity-oblivious routing
    (KG/SG) binds throughput at the cpulimit'ed workers; a
    capacity-proportional assignment sustains more."""
    keys = _keys(1.3)
    frac = np.ones(N)
    frac[:2] = 0.3
    fr = jnp.asarray(frac, jnp.float32)
    offered = float(frac.sum()) / (0.5e-3) * 0.75
    kg = simulation.simulate_deployment(
        P.key_grouping(keys, N), N, 0.5, fr, offered_rate_per_s=offered)
    sg = simulation.simulate_deployment(
        P.shuffle_grouping(keys, N), N, 0.5, fr, offered_rate_per_s=offered)
    # capacity-proportional routing (what CG converges to)
    probs = np.asarray(frac / frac.sum())
    rng = np.random.default_rng(0)
    cap_prop = jnp.asarray(rng.choice(N, size=keys.shape[0], p=probs),
                           jnp.int32)
    cg_like = simulation.simulate_deployment(
        cap_prop, N, 0.5, fr, offered_rate_per_s=offered)
    assert float(cg_like.throughput) > 1.5 * float(kg.throughput)
    assert float(cg_like.throughput) > 1.5 * float(sg.throughput)
    assert float(kg.mean_latency_ms) > float(cg_like.mean_latency_ms)
